//! `medge` — CLI for the experiment harness.
//!
//! Regenerates every table and figure of the paper's evaluation:
//! `medge fig4 | fig5 | fig6 | fig7 | fig8 | table2 | all`, plus
//! `medge ablation` (the future-work contextual multi-scheduler),
//! `medge trace` (trace-file tooling), and `medge sweep` — a parallel
//! scheduler×load scenario grid built on the [`medge::scenario`] API with
//! optional churn/heterogeneity stress and JSON row export. Argument
//! parsing is in-tree (the offline build has no clap): `--minutes F`,
//! `--seed N`, `--config PATH`, and the sweep options below.

use medge::config::SystemConfig;
use medge::experiments;
use medge::metrics::report;
use medge::scenario::{ScenarioBuilder, SchedKind, Sweep};
use medge::util::bench::CountingAlloc;
use medge::workload::trace::{Trace, TraceSpec};

/// Counting wrapper over the system allocator: one relaxed atomic per
/// allocation. It feeds `medge bench`'s steady-state `allocs/event`
/// gauge and is unobservable everywhere else.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn alloc_count() -> u64 {
    ALLOC.allocations()
}

const USAGE: &str = "\
medge — deadline-constrained DNN offloading at the mobile edge (paper reproduction)

USAGE: medge <COMMAND> [--minutes F] [--seed N] [--config PATH]

COMMANDS:
  fig4     Task completion, WPS_N vs RAS_N (weighted 1..4)
  fig5     Scheduling latency by scenario, both schedulers
  fig6     LP stage-3 completion by mechanism (bandwidth-interval sweep)
  fig7     Bandwidth-interval tests: completion across categories
  fig8     Network traffic congestion tests
  table2   Core allocation mix under congestion
  all      Everything above
  ablation Contextual multi-scheduler vs WPS vs RAS (future work)
  sweep    Parallel scenario grid (schedulers × weighted loads):
           --scheds wps,ras[,multi] --loads 1,2,3,4 --threads N
           --json PATH (export rows)  --churn (device 3 leaves/rejoins)
           --faults (add a faulted twin of every scenario)
  loadgen  Generative-workload sweep (schedulers × arrival processes over
           the heterogeneous edge-serving task catalog): offered load,
           admission drops, latency percentiles per priority class.
           --scheds wps,ras,multi  --procs SPEC[,SPEC...]  --cap N
           --threads N  --json PATH
           SPEC: poisson:RATE | mmpp:ON:OFF:MEAN_ON_S:MEAN_OFF_S
                 | diurnal:BASE:AMPLITUDE:PERIOD_S | closed:USERS:THINK_S
           (rates are arrivals/minute; default procs: poisson:6 and a
           bursty mmpp:24:1:45:90)
  accuracy Accuracy-frontier sweep (offered load × model-variant ladder
           depth × scheduler, stage-3 class under MMPP bursts): delivered
           accuracy vs deadlines met; depth-1 rows are the no-degradation
           twins. --scheds wps,ras,multi  --depths 1,2,3  --threads N
           --json PATH
  anytime  Anytime-inference grid (offered load × truncation {full, cut} ×
           scheduler on the staged stage-3 class under MMPP bursts): each
           _cut row runs the deadline-pressure controller against its
           _full twin — same seed and arrival plan — reporting deadlines
           met, pressure surveys/cuts, truncated completions, stages
           skipped, and delivered accuracy.
           --scheds wps,ras,multi,greedy  --quick (short CI smoke grid)
           --threads N  --json PATH
  energy   Energy & cloud-tier grids (battery-constrained fleet, cloud
           burst under overload, diurnal drain): fleet joules, battery
           timelines, deadline-met-per-kilojoule, cloud placements.
           --grid battery|burst|diurnal|all (default all)
           --scheds wps,ras,energy  --battery J  --power PROFILE
           --wan BPS  --rtt MS  --threads N  --json PATH
           PROFILE: pi2b | zero | IDLE:HP:TWO:FOUR:TX:RX (watts)
  chaos    Seeded fault-campaign runner: randomized crash/partition/
           packet-loss/probe-loss schedules with every robustness knob on
           (failure detector, offload timeout + retry, hedging, bandwidth
           staleness), swept across seeds × schedulers (wps, ras, multi)
           and hard-checked against the conservation invariants (no task
           leaked, lost, or double-credited). Nonzero exit on the first
           violated invariant.
           --seeds N (schedules per scheduler, default 50)
           --quick (10 seeds, the CI smoke campaign)  --json PATH
  bench    Hot-path micro/macro benchmark suite (slab vs hashmap,
           incremental vs rescanning medium, engine event rate,
           steady-state allocs/event, end-to-end sweep):
           --quick (short CI smoke sampling)
           --json [PATH] (write the trajectory file;
           default BENCH_hotpath.json at the repo root)
  trace    Flight-recorder / trace-file tooling.
           Workload mode (default): generate a conveyor trace file:
           --spec S --frames N --out PATH
           (S: uniform | weighted1..weighted4)
           Perfetto mode (--run or --quick): run one flight-recorded
           scenario and write its Chrome-trace JSON timeline (open in
           ui.perfetto.dev): --run [--out PATH] | --quick (short CI
           smoke run); default output TRACE_perfetto.json

OPTIONS:
  --minutes F   simulated experiment duration in minutes (default 30)
  --seed N      RNG seed (traces, shuffles, probe hosts, bursts)
  --config P    key-value config file overriding the paper defaults
  --scheds L    sweep/loadgen: comma list of schedulers (default wps,ras;
                loadgen defaults to wps,ras,multi)
  --loads L     sweep: comma list of weighted loads 1..4 (default 1,2,3,4)
  --devices N   fleet size override (scale-out runs; past 512 devices the
                schedulers auto-shard the fleet into ~√n-device cells)
  --procs L     loadgen: comma list of arrival-process specs
  --depths L    accuracy: comma list of ladder depths 1..3 (default 1,2,3)
  --cap N       loadgen: admission cap on in-flight tasks (default 0 = open)
  --seeds N     chaos: randomized schedules per scheduler (default 50)
  --grid G      energy: which grid(s) to run (battery | burst | diurnal | all)
  --battery J   energy: per-device battery capacity in joules (default 2000)
  --power P     energy: power profile (pi2b | zero | IDLE:HP:TWO:FOUR:TX:RX)
  --wan BPS     energy: cloud WAN bandwidth, bits/s (default 20e6)
  --rtt MS      energy: cloud WAN round-trip time, ms (default 40)
  --threads N   sweep/loadgen: worker threads (default: available parallelism)
  --trace[=P]   sweep/loadgen/accuracy/energy/chaos: re-run the grid's first
                scenario with a flight recorder attached and write its
                Perfetto/Chrome-trace JSON to P (default TRACE_perfetto.json).
                Runs are deterministic and the recorder draws no RNG, so the
                exported timeline is byte-faithful to the grid row.
  --json P      sweep/loadgen: write the metric rows as a JSON array to P
  --churn       sweep: device 3 leaves at 25% and rejoins at 60% of the run
  --faults      sweep: add a faulted twin of every scenario (suffix F):
                5% packet loss, 25% probe loss, and device 0 crashing
                at 30% / recovering at 55% of the run
";

struct Args {
    cmd: String,
    minutes: f64,
    seed: Option<u64>,
    config: Option<std::path::PathBuf>,
    spec: String,
    frames: usize,
    out: Option<std::path::PathBuf>,
    /// None = the subcommand's own default (sweep: wps,ras;
    /// loadgen: wps,ras,multi) — an explicit flag is never overridden.
    scheds: Option<String>,
    loads: String,
    devices: Option<usize>,
    procs: Option<String>,
    depths: Option<String>,
    cap: usize,
    seeds: Option<usize>,
    /// `medge energy` flags, parsed strictly at dispatch time (the
    /// raw strings are kept here so a bad value errors with the full
    /// flag context, never panics).
    grid: String,
    battery: Option<String>,
    power: Option<String>,
    wan: Option<String>,
    rtt: Option<String>,
    threads: Option<usize>,
    json: Option<std::path::PathBuf>,
    /// `--json` was passed (with or without a path) — `bench` writes its
    /// default trajectory file when the path is omitted.
    json_flag: bool,
    churn: bool,
    faults: bool,
    quick: bool,
    /// `--trace[=PATH]` was passed: export the grid's first scenario as a
    /// Perfetto timeline. The path stays `None` for the bare form (the
    /// default `TRACE_perfetto.json` is resolved at dispatch time).
    trace_flag: bool,
    trace_path: Option<std::path::PathBuf>,
    /// `medge trace --run`: the Perfetto run mode (vs. workload-file
    /// generation, the default mode of the `trace` subcommand).
    run: bool,
}

fn parse_args() -> anyhow::Result<Args> {
    let mut args = Args {
        cmd: String::new(),
        minutes: 30.0,
        seed: None,
        config: None,
        spec: "weighted4".to_string(),
        frames: 96,
        out: None,
        scheds: None,
        loads: "1,2,3,4".to_string(),
        devices: None,
        procs: None,
        depths: None,
        cap: 0,
        seeds: None,
        grid: "all".to_string(),
        battery: None,
        power: None,
        wan: None,
        rtt: None,
        threads: None,
        json: None,
        json_flag: false,
        churn: false,
        faults: false,
        quick: false,
        trace_flag: false,
        trace_path: None,
        run: false,
    };
    fn value(
        it: &mut std::iter::Peekable<impl Iterator<Item = String>>,
        name: &str,
    ) -> anyhow::Result<String> {
        it.next().ok_or_else(|| anyhow::anyhow!("{name} needs a value"))
    }
    let mut it = std::env::args().skip(1).peekable();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--minutes" => args.minutes = value(&mut it, "--minutes")?.parse()?,
            "--seed" => args.seed = Some(value(&mut it, "--seed")?.parse()?),
            "--config" => args.config = Some(value(&mut it, "--config")?.into()),
            "--spec" => args.spec = value(&mut it, "--spec")?,
            "--frames" => args.frames = value(&mut it, "--frames")?.parse()?,
            "--out" => args.out = Some(value(&mut it, "--out")?.into()),
            "--scheds" => args.scheds = Some(value(&mut it, "--scheds")?),
            "--loads" => args.loads = value(&mut it, "--loads")?,
            "--devices" => args.devices = Some(value(&mut it, "--devices")?.parse()?),
            "--procs" => args.procs = Some(value(&mut it, "--procs")?),
            "--depths" => args.depths = Some(value(&mut it, "--depths")?),
            "--cap" => args.cap = value(&mut it, "--cap")?.parse()?,
            "--seeds" => args.seeds = Some(value(&mut it, "--seeds")?.parse()?),
            "--grid" => args.grid = value(&mut it, "--grid")?,
            "--battery" => args.battery = Some(value(&mut it, "--battery")?),
            "--power" => args.power = Some(value(&mut it, "--power")?),
            "--wan" => args.wan = Some(value(&mut it, "--wan")?),
            "--rtt" => args.rtt = Some(value(&mut it, "--rtt")?),
            "--threads" => args.threads = Some(value(&mut it, "--threads")?.parse()?),
            "--json" => {
                // Path is optional for `bench` (defaults to the repo-root
                // trajectory file); `sweep` validates it got one.
                args.json_flag = true;
                args.json = match it.peek() {
                    Some(v) if !v.starts_with('-') => {
                        Some(value(&mut it, "--json")?.into())
                    }
                    _ => None,
                };
            }
            "--churn" => args.churn = true,
            "--faults" => args.faults = true,
            "--quick" => args.quick = true,
            "--run" => args.run = true,
            "--trace" => args.trace_flag = true,
            t if t.starts_with("--trace=") => {
                args.trace_flag = true;
                args.trace_path = Some(parse_trace_eq(t)?);
            }
            "-h" | "--help" => {
                print!("{USAGE}");
                std::process::exit(0);
            }
            cmd if !cmd.starts_with('-') && args.cmd.is_empty() => args.cmd = cmd.to_string(),
            other => anyhow::bail!("unknown argument: {other}\n{USAGE}"),
        }
    }
    if args.cmd.is_empty() {
        anyhow::bail!("missing command\n{USAGE}");
    }
    Ok(args)
}

/// Parse `--wan BPS` — strictly positive and finite bits/s, mirroring
/// the strictness of [`medge::workload::gen::ArrivalProcess::parse`]:
/// a bad value is an error, never a panic or a silent default.
fn parse_wan_bps(s: &str) -> anyhow::Result<f64> {
    let v = s
        .parse::<f64>()
        .map_err(|_| anyhow::anyhow!("WAN bandwidth '{s}' is not a number"))?;
    anyhow::ensure!(
        v.is_finite() && v > 0.0,
        "WAN bandwidth must be a finite positive bits/s figure, got '{s}'"
    );
    Ok(v)
}

/// Parse `--rtt MS` — strictly non-negative and finite milliseconds.
fn parse_rtt_ms(s: &str) -> anyhow::Result<f64> {
    let v = s
        .parse::<f64>()
        .map_err(|_| anyhow::anyhow!("WAN RTT '{s}' is not a number"))?;
    anyhow::ensure!(
        v.is_finite() && v >= 0.0,
        "WAN RTT must be a finite non-negative millisecond figure, got '{s}'"
    );
    Ok(v)
}

/// Which of the three energy grids `--grid` selects:
/// `(battery, burst, diurnal)`.
fn parse_energy_grids(s: &str) -> anyhow::Result<(bool, bool, bool)> {
    match s {
        "all" => Ok((true, true, true)),
        "battery" => Ok((true, false, false)),
        "burst" => Ok((false, true, false)),
        "diurnal" => Ok((false, false, true)),
        other => anyhow::bail!("unknown energy grid: {other} (battery | burst | diurnal | all)"),
    }
}

/// Default output path for `--trace` / `medge trace --run`.
const TRACE_DEFAULT_OUT: &str = "TRACE_perfetto.json";

/// Parse the `--trace=PATH` form strictly: an empty path is an error,
/// never a silent fall-through to the default filename.
fn parse_trace_eq(arg: &str) -> anyhow::Result<std::path::PathBuf> {
    let p = arg.strip_prefix("--trace=").expect("caller matched the prefix");
    anyhow::ensure!(!p.is_empty(), "--trace= needs a non-empty PATH");
    Ok(p.into())
}

/// Resolve the `--trace[=PATH]` output path.
fn trace_out(args: &Args) -> std::path::PathBuf {
    args.trace_path.clone().unwrap_or_else(|| TRACE_DEFAULT_OUT.into())
}

/// Re-run `scenario` with a flight recorder attached and write its
/// Perfetto/Chrome-trace JSON to `path`. Engine runs are deterministic
/// and the recorder makes no RNG draws, so the exported timeline is
/// byte-faithful to the metrics row the grid already produced for the
/// same scenario.
fn export_scenario_trace(
    scenario: &medge::scenario::Scenario,
    path: &std::path::Path,
) -> anyhow::Result<()> {
    let mut s = scenario.clone();
    s.extras.trace_capacity = medge::obs::DEFAULT_CAPACITY;
    let mut eng = s.engine();
    eng.drain();
    let json = eng.trace_json().expect("recorder attached above");
    std::fs::write(path, &json)?;
    let r = eng.recorder().expect("recorder attached above");
    println!(
        "wrote Perfetto trace of {}: {} span records kept ({} seen, {} decisions) to {}",
        s.name,
        r.len(),
        r.total_seen(),
        r.decisions(),
        path.display()
    );
    Ok(())
}

/// Build the sweep grid: schedulers × weighted loads, with optional churn
/// stress, on a shared base config.
fn build_sweep(cfg: &SystemConfig, args: &Args) -> anyhow::Result<Sweep> {
    let kinds: Vec<SchedKind> = args
        .scheds
        .as_deref()
        .unwrap_or("wps,ras")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(SchedKind::parse)
        .collect::<anyhow::Result<_>>()?;
    let loads: Vec<u8> = args
        .loads
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| {
            let n: u8 = s.parse().map_err(|_| anyhow::anyhow!("bad load: {s}"))?;
            anyhow::ensure!((1..=4).contains(&n), "load out of range 1..4: {n}");
            Ok(n)
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(!kinds.is_empty() && !loads.is_empty(), "empty sweep grid");
    anyhow::ensure!(
        !args.churn || cfg.n_devices >= 2,
        "--churn needs at least 2 devices (fleet has {})",
        cfg.n_devices
    );
    let mut sweep = Sweep::new();
    if let Some(t) = args.threads {
        sweep = sweep.threads(t);
    }
    // Churn stress targets the last device of the configured fleet, not a
    // fixed index: a smaller --config fleet must not turn the "leave" into
    // a no-op and the "join" into a capacity boost.
    let churn_device = cfg.n_devices.saturating_sub(1);
    for &n in &loads {
        for &kind in &kinds {
            let mut b = ScenarioBuilder::new()
                .config(cfg.clone())
                .scheduler(kind)
                .trace(TraceSpec::Weighted(n))
                .minutes(args.minutes)
                .named(format!("{}_{}", kind.label(), n));
            if args.churn {
                // Stress regime: the device drops out a quarter of the way
                // through and rejoins at 60 % of the run.
                let total_s = args.minutes * 60.0;
                b = b.leave_at(total_s * 0.25, churn_device).join_at(total_s * 0.60, churn_device);
            }
            sweep = sweep.add(b.clone().build());
            if args.faults {
                // Fault axis: a faulted twin of the same scenario — a
                // lossy link, a quarter of probe pings dropped, and
                // device 0 crashing mid-run with work in flight. Device 0
                // (not the churn device) so that --churn --faults
                // composes: the graceful leave and the crash must not
                // collapse onto the same device and no-op each other.
                let total_s = args.minutes * 60.0;
                sweep = sweep.add(
                    b.named(format!("{}_{}F", kind.label(), n))
                        .loss_rate(0.05)
                        .probe_loss(0.25)
                        .crash_at(total_s * 0.30, 0)
                        .recover_at(total_s * 0.55, 0)
                        .build(),
                );
            }
        }
    }
    Ok(sweep)
}

fn main() -> anyhow::Result<()> {
    let args = parse_args()?;
    let mut cfg = match &args.config {
        Some(p) => SystemConfig::from_kv_file(p)?,
        None => SystemConfig::default(),
    };
    if let Some(seed) = args.seed {
        cfg.seed = seed;
    }
    if let Some(n) = args.devices {
        anyhow::ensure!(n >= 1, "--devices needs at least 1 device");
        cfg.n_devices = n;
    }
    let minutes = args.minutes;

    match args.cmd.as_str() {
        "fig4" => {
            let runs = experiments::fig4_fig5(&cfg, minutes);
            print!("{}", report::fig4(&runs));
        }
        "fig5" => {
            let runs = experiments::fig4_fig5(&cfg, minutes);
            print!("{}", report::fig5(&runs));
        }
        "fig6" => {
            let runs = experiments::fig6_fig7(&cfg, minutes);
            print!("{}", report::fig6(&runs));
        }
        "fig7" => {
            let runs = experiments::fig6_fig7(&cfg, minutes);
            print!("{}", report::fig7(&runs));
        }
        "fig8" => {
            let runs = experiments::fig8_table2(&cfg, minutes);
            print!("{}", report::fig8(&runs));
        }
        "table2" => {
            let runs = experiments::fig8_table2(&cfg, minutes);
            print!("{}", report::table2(&runs));
        }
        "all" => {
            let main_runs = experiments::fig4_fig5(&cfg, minutes);
            print!("{}", report::fig4(&main_runs));
            print!("{}", report::fig5(&main_runs));
            let bit_runs = experiments::fig6_fig7(&cfg, minutes);
            print!("{}", report::fig6(&bit_runs));
            print!("{}", report::fig7(&bit_runs));
            let traffic_runs = experiments::fig8_table2(&cfg, minutes);
            print!("{}", report::fig8(&traffic_runs));
            print!("{}", report::table2(&traffic_runs));
        }
        "ablation" => {
            let runs = experiments::ablation_multi(&cfg, minutes);
            print!("{}", report::fig4(&runs));
            print!("{}", report::fig5(&runs));
        }
        "bench" => {
            let rows = experiments::hotpath::run_suite(&experiments::hotpath::SuiteOptions {
                quick: args.quick,
                alloc_count: Some(alloc_count),
            });
            if args.json_flag {
                // Default lands in the invoker's working directory (the
                // repo root in CI and the documented workflow) — resolved
                // at runtime, never a path baked in at build time.
                let path = args
                    .json
                    .unwrap_or_else(|| std::path::PathBuf::from("BENCH_hotpath.json"));
                let provenance = format!(
                    "medge bench --json{} (commit the refreshed file to extend the trajectory)",
                    if args.quick { " --quick" } else { "" }
                );
                std::fs::write(&path, medge::util::bench::json_report("hot_path", &provenance, &rows))?;
                println!("\nwrote {} bench rows to {}", rows.len(), path.display());
            }
        }
        "sweep" => {
            anyhow::ensure!(
                !(args.json_flag && args.json.is_none()),
                "sweep --json needs a PATH"
            );
            let sweep = build_sweep(&cfg, &args)?;
            eprintln!(
                "sweep: {} scenarios × {:.1} simulated minutes{}{}",
                sweep.len(),
                minutes,
                if args.churn { " (churn stress on)" } else { "" },
                if args.faults { " (fault axis on)" } else { "" }
            );
            let runs = sweep.run();
            print!("{}", report::fig4(&runs));
            print!("{}", report::fig5(&runs));
            if args.faults {
                print!("{}", report::faults(&runs));
            }
            if let Some(path) = &args.json {
                std::fs::write(path, report::json_rows(&runs))?;
                println!("\nwrote {} JSON rows to {}", runs.len(), path.display());
            }
            if args.trace_flag {
                let first = sweep.scenarios().first().expect("non-empty grid ensured above");
                export_scenario_trace(first, &trace_out(&args))?;
            }
        }
        "loadgen" => {
            anyhow::ensure!(
                !(args.json_flag && args.json.is_none()),
                "loadgen --json needs a PATH"
            );
            // All three schedulers by default: the acceptance sweep
            // contrasts the abstraction models under open-loop load. An
            // explicit --scheds always wins.
            let kinds: Vec<SchedKind> = args
                .scheds
                .as_deref()
                .unwrap_or("wps,ras,multi")
                .split(',')
                .filter(|s| !s.is_empty())
                .map(SchedKind::parse)
                .collect::<anyhow::Result<_>>()?;
            let procs: Vec<medge::workload::gen::ArrivalProcess> = match &args.procs {
                Some(list) => list
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(medge::workload::gen::ArrivalProcess::parse)
                    .collect::<anyhow::Result<_>>()?,
                None => experiments::default_loadgen_processes(),
            };
            anyhow::ensure!(!kinds.is_empty() && !procs.is_empty(), "empty loadgen grid");
            let mut sweep = experiments::loadgen_grid(&cfg, &kinds, &procs, minutes, args.cap);
            if let Some(t) = args.threads {
                sweep = sweep.threads(t);
            }
            eprintln!(
                "loadgen: {} scenarios × {:.1} simulated minutes (cap {})",
                sweep.len(),
                minutes,
                if args.cap == 0 { "open".to_string() } else { args.cap.to_string() }
            );
            let runs = sweep.run();
            print!("{}", report::loadgen(&runs));
            print!("{}", report::fig4(&runs));
            print!("{}", report::percentiles(&runs));
            if let Some(path) = &args.json {
                std::fs::write(path, report::json_rows(&runs))?;
                println!("\nwrote {} JSON rows to {}", runs.len(), path.display());
            }
            if args.trace_flag {
                let first = sweep.scenarios().first().expect("non-empty grid ensured above");
                export_scenario_trace(first, &trace_out(&args))?;
            }
        }
        "accuracy" => {
            anyhow::ensure!(
                !(args.json_flag && args.json.is_none()),
                "accuracy --json needs a PATH"
            );
            let kinds: Vec<SchedKind> = args
                .scheds
                .as_deref()
                .unwrap_or("wps,ras,multi")
                .split(',')
                .filter(|s| !s.is_empty())
                .map(SchedKind::parse)
                .collect::<anyhow::Result<_>>()?;
            let depths = experiments::parse_depths(args.depths.as_deref().unwrap_or("1,2,3"))?;
            anyhow::ensure!(!kinds.is_empty(), "empty accuracy grid");
            let mut sweep = experiments::accuracy_frontier(&cfg, &kinds, &depths, minutes);
            if let Some(t) = args.threads {
                sweep = sweep.threads(t);
            }
            eprintln!(
                "accuracy: {} scenarios × {:.1} simulated minutes (depths {:?})",
                sweep.len(),
                minutes,
                depths
            );
            let runs = sweep.run();
            print!("{}", report::accuracy(&runs));
            print!("{}", report::loadgen(&runs));
            if let Some(path) = &args.json {
                std::fs::write(path, report::json_rows(&runs))?;
                println!("\nwrote {} JSON rows to {}", runs.len(), path.display());
            }
            if args.trace_flag {
                let first = sweep.scenarios().first().expect("empty accuracy grid rejected above");
                export_scenario_trace(first, &trace_out(&args))?;
            }
        }
        "anytime" => {
            anyhow::ensure!(
                !(args.json_flag && args.json.is_none()),
                "anytime --json needs a PATH"
            );
            let kinds: Vec<SchedKind> = args
                .scheds
                .as_deref()
                .unwrap_or("wps,ras,multi,greedy")
                .split(',')
                .filter(|s| !s.is_empty())
                .map(SchedKind::parse)
                .collect::<anyhow::Result<_>>()?;
            anyhow::ensure!(!kinds.is_empty(), "empty anytime grid");
            // --quick: the CI smoke grid — long enough for the MMPP
            // bursts to bite (and the cut twins to truncate), short
            // enough for a PR gate.
            let minutes = if args.quick { 4.0 } else { minutes };
            let mut sweep = experiments::anytime_grid(&cfg, &kinds, minutes);
            if let Some(t) = args.threads {
                sweep = sweep.threads(t);
            }
            eprintln!(
                "anytime: {} scenarios × {minutes:.1} simulated minutes (survey {}s, backlog {})",
                sweep.len(),
                experiments::ANYTIME_CHECK_S,
                experiments::ANYTIME_BACKLOG
            );
            let runs = sweep.run();
            print!("{}", report::anytime(&runs));
            print!("{}", report::accuracy(&runs));
            if let Some(path) = &args.json {
                std::fs::write(path, report::json_rows(&runs))?;
                println!("\nwrote {} JSON rows to {}", runs.len(), path.display());
            }
            if args.trace_flag {
                let first = sweep.scenarios().first().expect("empty anytime grid rejected above");
                export_scenario_trace(first, &trace_out(&args))?;
            }
        }
        "energy" => {
            anyhow::ensure!(
                !(args.json_flag && args.json.is_none()),
                "energy --json needs a PATH"
            );
            // Strict flag parsing up front: every bad value errors with
            // its flag context before any scenario is built.
            let kinds: Vec<SchedKind> = args
                .scheds
                .as_deref()
                .unwrap_or("wps,ras,energy")
                .split(',')
                .filter(|s| !s.is_empty())
                .map(SchedKind::parse)
                .collect::<anyhow::Result<_>>()?;
            anyhow::ensure!(!kinds.is_empty(), "empty energy grid");
            let (battery_grid, burst_grid, diurnal_grid) = parse_energy_grids(&args.grid)?;
            let model = medge::energy::EnergyModel::parse(
                args.power.as_deref().unwrap_or("pi2b"),
            )?;
            let battery_j = match &args.battery {
                Some(s) => medge::energy::parse_battery_j(s)?,
                None => 2000.0,
            };
            cfg.cloud_wan_bps = match &args.wan {
                Some(s) => parse_wan_bps(s)?,
                None => 20e6,
            };
            cfg.cloud_rtt_ms = match &args.rtt {
                Some(s) => parse_rtt_ms(s)?,
                None => 40.0,
            };
            let mut runs = Vec::new();
            // First scenario of the first selected grid: the `--trace`
            // export target (the sweeps are consumed by the fan below).
            let mut traced: Option<medge::scenario::Scenario> = None;
            let mut fan = |mut sweep: Sweep, what: &str| {
                if let Some(t) = args.threads {
                    sweep = sweep.threads(t);
                }
                eprintln!(
                    "energy/{what}: {} scenarios × {minutes:.1} simulated minutes",
                    sweep.len()
                );
                if traced.is_none() {
                    traced = sweep.scenarios().first().cloned();
                }
                runs.extend(sweep.run());
            };
            if battery_grid {
                fan(
                    experiments::energy_battery_grid(&cfg, &kinds, minutes, battery_j, &model),
                    "battery",
                );
            }
            if burst_grid {
                fan(experiments::cloud_burst_grid(&cfg, &kinds, minutes), "burst");
            }
            if diurnal_grid {
                fan(
                    experiments::diurnal_drain_grid(
                        &cfg,
                        &kinds,
                        minutes,
                        &[battery_j / 2.0, battery_j * 2.0],
                        &model,
                    ),
                    "diurnal",
                );
            }
            print!("{}", report::energy(&runs));
            print!("{}", report::fig4(&runs));
            if let Some(path) = &args.json {
                std::fs::write(path, report::json_rows(&runs))?;
                println!("\nwrote {} JSON rows to {}", runs.len(), path.display());
            }
            if args.trace_flag {
                let s = traced
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("--trace needs a non-empty energy grid"))?;
                export_scenario_trace(s, &trace_out(&args))?;
            }
        }
        "chaos" => {
            anyhow::ensure!(
                !(args.json_flag && args.json.is_none()),
                "chaos --json needs a PATH"
            );
            let seeds = args.seeds.unwrap_or(if args.quick {
                experiments::CHAOS_QUICK_SEEDS
            } else {
                experiments::CHAOS_SEEDS
            });
            anyhow::ensure!(seeds >= 1, "--seeds needs at least 1 schedule");
            eprintln!(
                "chaos: {seeds} schedules × {} schedulers × {minutes:.1} simulated minutes",
                experiments::CHAOS_KINDS.len()
            );
            // Aborts with a seed-labelled error (nonzero exit) on the
            // first violated conservation invariant.
            let runs = experiments::chaos_campaign(&cfg, seeds, minutes)?;
            print!("{}", report::robustness(&runs));
            print!("{}", report::faults(&runs));
            if let Some(path) = &args.json {
                std::fs::write(path, report::json_rows(&runs))?;
                println!("\nwrote {} JSON rows to {}", runs.len(), path.display());
            }
            if args.trace_flag {
                // The campaign's first cell (a failing cell dumps its own
                // recorder to CHAOS_FLIGHT_RECORDER.json before this point).
                let s = experiments::chaos_scenario(&cfg, experiments::CHAOS_KINDS[0], 0, minutes);
                export_scenario_trace(&s, &trace_out(&args))?;
            }
            println!("\nchaos: {} runs, every invariant held", runs.len());
        }
        "trace" => {
            if args.run || args.quick {
                // Perfetto run mode: one flight-recorded scenario, full
                // span taxonomy plus one DecisionRecord per scheduler
                // decision, exported as Chrome-trace JSON. `--quick` is
                // the CI smoke variant (a short fixed-frame run).
                let kind = match args.scheds.as_deref() {
                    Some(list) => SchedKind::parse(list.split(',').next().unwrap_or(""))?,
                    None => SchedKind::Ras,
                };
                let mut b = ScenarioBuilder::new()
                    .config(cfg.clone())
                    .scheduler(kind)
                    .trace(TraceSpec::parse(&args.spec)?);
                b = if args.quick { b.frames(12) } else { b.minutes(minutes) };
                let path = args.out.clone().unwrap_or_else(|| trace_out(&args));
                export_scenario_trace(&b.build(), &path)?;
            } else {
                let out = args
                    .out
                    .ok_or_else(|| anyhow::anyhow!("trace needs --out PATH (or --run/--quick for the Perfetto mode)"))?;
                let t = Trace::generate(TraceSpec::parse(&args.spec)?, cfg.n_devices, args.frames, cfg.seed);
                t.save(&out)?;
                println!(
                    "wrote {} frames ({:.2} mean DNN load) to {}",
                    args.frames,
                    t.mean_dnn_load(),
                    out.display()
                );
            }
        }
        other => anyhow::bail!("unknown command: {other}\n{USAGE}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wan_bandwidth_parser_is_strict() {
        assert_eq!(parse_wan_bps("20e6").unwrap(), 20e6);
        assert_eq!(parse_wan_bps("1000000").unwrap(), 1e6);
        assert!(parse_wan_bps("0").is_err(), "zero bandwidth is degenerate");
        assert!(parse_wan_bps("-5e6").is_err(), "negative");
        assert!(parse_wan_bps("inf").is_err(), "non-finite");
        assert!(parse_wan_bps("NaN").is_err(), "non-finite");
        assert!(parse_wan_bps("fast").is_err(), "not a number");
        assert!(parse_wan_bps("").is_err(), "empty");
    }

    #[test]
    fn rtt_parser_is_strict() {
        assert_eq!(parse_rtt_ms("40").unwrap(), 40.0);
        assert_eq!(parse_rtt_ms("0").unwrap(), 0.0, "zero RTT is a valid LAN-like WAN");
        assert!(parse_rtt_ms("-1").is_err(), "negative");
        assert!(parse_rtt_ms("inf").is_err(), "non-finite");
        assert!(parse_rtt_ms("soon").is_err(), "not a number");
    }

    #[test]
    fn energy_grid_selector_is_strict() {
        assert_eq!(parse_energy_grids("all").unwrap(), (true, true, true));
        assert_eq!(parse_energy_grids("battery").unwrap(), (true, false, false));
        assert_eq!(parse_energy_grids("burst").unwrap(), (false, true, false));
        assert_eq!(parse_energy_grids("diurnal").unwrap(), (false, false, true));
        assert!(parse_energy_grids("everything").is_err());
        assert!(parse_energy_grids("").is_err());
    }

    #[test]
    fn trace_flag_parser_is_strict() {
        assert_eq!(
            parse_trace_eq("--trace=out.json").unwrap(),
            std::path::PathBuf::from("out.json")
        );
        assert_eq!(
            parse_trace_eq("--trace=/tmp/run trace.json").unwrap(),
            std::path::PathBuf::from("/tmp/run trace.json"),
            "spaces survive the = form"
        );
        assert!(parse_trace_eq("--trace=").is_err(), "empty path");
    }

    #[test]
    fn energy_flag_values_parse_through_the_library_paths() {
        // The dispatch arm routes --power / --battery through the strict
        // library parsers; spot-check both directions here so a CLI
        // regression cannot silently decouple from them.
        assert!(medge::energy::EnergyModel::parse("pi2b").is_ok());
        assert!(medge::energy::EnergyModel::parse("1.1:0.9:1.5:2.5:0.45:0.35").is_ok());
        assert!(medge::energy::EnergyModel::parse("1.1:0.9").is_err(), "field count");
        assert!(medge::energy::EnergyModel::parse("pi9000").is_err(), "unknown profile");
        assert!(medge::energy::parse_battery_j("2000").is_ok());
        assert!(medge::energy::parse_battery_j("0").is_err(), "must be positive");
        assert!(medge::energy::parse_battery_j("plenty").is_err(), "not a number");
    }
}
