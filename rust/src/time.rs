//! Virtual time for the discrete-event simulator.
//!
//! All simulator and scheduler timestamps are `SimTime`: microseconds since
//! the start of the experiment. Using integer microseconds keeps the
//! discrete-event engine deterministic (no float drift) while being fine
//! enough to express sub-millisecond scheduling latencies (the paper reports
//! latencies from ~1 ms up to ~250 ms).

/// Microseconds since experiment start.
pub type SimTime = u64;

/// A span of virtual time, in microseconds.
pub type SimDuration = u64;

/// A sentinel "far future" used as the open end of availability windows.
/// Kept well below `u64::MAX` so additions never overflow.
pub const INFINITY: SimTime = u64::MAX / 4;

/// Convert seconds (f64) to `SimTime` microseconds.
#[inline]
pub fn secs(s: f64) -> SimDuration {
    (s * 1_000_000.0).round() as SimDuration
}

/// Convert milliseconds (f64) to `SimTime` microseconds.
#[inline]
pub fn millis(ms: f64) -> SimDuration {
    (ms * 1_000.0).round() as SimDuration
}

/// Convert a `SimTime`/`SimDuration` to fractional seconds (for reports).
#[inline]
pub fn as_secs(t: SimTime) -> f64 {
    t as f64 / 1_000_000.0
}

/// Convert a `SimTime`/`SimDuration` to fractional milliseconds (for reports).
#[inline]
pub fn as_millis(t: SimTime) -> f64 {
    t as f64 / 1_000.0
}

/// Round `t` up to the next multiple of `unit` (used by the network link
/// discretisation to align its origin, t_r in the paper).
#[inline]
pub fn round_up(t: SimTime, unit: SimDuration) -> SimTime {
    debug_assert!(unit > 0);
    t.div_ceil(unit) * unit
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn secs_roundtrip() {
        assert_eq!(secs(1.0), 1_000_000);
        assert_eq!(secs(0.98), 980_000);
        assert_eq!(secs(16.862), 16_862_000);
        assert_eq!(millis(1.5), 1_500);
        assert!((as_secs(secs(18.86)) - 18.86).abs() < 1e-9);
    }

    #[test]
    fn round_up_aligns() {
        assert_eq!(round_up(0, 10), 0);
        assert_eq!(round_up(1, 10), 10);
        assert_eq!(round_up(10, 10), 10);
        assert_eq!(round_up(11, 10), 20);
    }

    #[test]
    fn infinity_headroom() {
        // Arithmetic on INFINITY plus any realistic duration must not wrap.
        assert!(INFINITY.checked_add(secs(1e9)).is_some());
    }
}
