//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! implements exactly the subset medge uses: [`Error`], [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics match upstream for that subset: any
//! `std::error::Error` converts into [`Error`] via `?`, context wraps the
//! message, and `Debug` prints the cause chain (what `fn main() ->
//! anyhow::Result<()>` shows on exit).

use std::fmt;

/// A dynamic error with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap with higher-level context (outermost first, as upstream).
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg), source: self.source }
    }

    /// The root cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> + '_ {
        let mut next = self.source.as_deref().map(|e| e as &(dyn std::error::Error + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:\n    {src}")?;
        }
        Ok(())
    }
}

// `Error` deliberately does not implement `std::error::Error` (same as
// upstream), which is what makes this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or a displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond))
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 12);
        fn bad() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(bad().is_err());
    }

    #[test]
    fn context_wraps_outermost_first() {
        let e: Result<()> = Err(Error::from(io_err())).context("loading config");
        let msg = format!("{}", e.unwrap_err());
        assert!(msg.starts_with("loading config: "), "{msg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        let name = "x";
        let e = anyhow!("bad {name}");
        assert_eq!(format!("{e}"), "bad x");
        let e = anyhow!(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");
        let e = anyhow!("{} + {}", 1, 2);
        assert_eq!(format!("{e}"), "1 + 2");
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3, "math broke: {}", 2);
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "math broke: 2");
        fn g() -> Result<()> {
            bail!("stop")
        }
        assert!(g().is_err());
    }

    #[test]
    fn debug_shows_cause() {
        let e = Error::from(io_err()).context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("top"));
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("gone"));
    }
}
