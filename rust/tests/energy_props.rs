//! Energy & cloud-tier property suite: conservation of the integrated
//! joules, battery bounds, the no-model/zero-model equivalence wall, and
//! the two acceptance claims of the three-tier subsystem —
//!
//! * an MMPP-overload scenario with the cloud tier reachable delivers
//!   strictly more deadlines than its edge-only twin on every scheduler;
//! * the energy-aware scheduler beats the deadline-only ones on
//!   deadlines met per kilojoule in the battery-constrained grid.

use medge::config::SystemConfig;
use medge::energy::EnergyModel;
use medge::experiments;
use medge::metrics::Metrics;
use medge::scenario::{ScenarioBuilder, SchedKind};
use medge::workload::trace::TraceSpec;

fn powered(kind: SchedKind, seed: u64, battery_j: Option<f64>) -> Metrics {
    let mut b = ScenarioBuilder::new()
        .scheduler(kind)
        .trace(TraceSpec::Weighted(4))
        .frames(14)
        .seed(seed)
        .energy(EnergyModel::pi2b())
        .cloud(20e6, 40.0)
        .crash_at(50.0, 0)
        .recover_at(130.0, 0)
        .loss_rate(0.05);
    if let Some(j) = battery_j {
        b = b.battery_j(j);
    }
    b.build().run()
}

/// The integrator keeps per-component and total accumulators separately;
/// conservation (`idle + active + tx + rx == total`) must hold to
/// floating-point tolerance on every run — mains or battery, clean or
/// faulted, edge or three-tier.
#[test]
fn energy_components_sum_to_total() {
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Energy] {
        for battery in [None, Some(400.0)] {
            let m = powered(kind, 811, battery);
            let parts = m.energy_idle_j + m.energy_active_j + m.energy_tx_j + m.energy_rx_j;
            assert!(m.energy_total_j > 0.0, "{}: nothing integrated", m.label);
            assert!(
                (parts - m.energy_total_j).abs() <= 1e-6 * m.energy_total_j.max(1.0),
                "{}: conservation violated: {parts} != {}",
                m.label,
                m.energy_total_j
            );
        }
    }
}

/// Batteries only discharge: every final level sits in `[0, capacity]`,
/// and a strictly larger budget never finishes lower (same seed, same
/// events up to the first depletion; extra capacity can only add margin).
#[test]
fn battery_levels_stay_bounded_and_capacity_helps() {
    let cap = 350.0;
    let m = powered(SchedKind::Ras, 823, Some(cap));
    assert_eq!(m.battery_final_j.len(), 4);
    for (d, &j) in m.battery_final_j.iter().enumerate() {
        assert!((0.0..=cap).contains(&j), "{}: device {d} battery {j} outside [0, {cap}]", m.label);
    }
    assert!(m.battery_depletions > 0, "a 350 J budget must deplete under weighted-4 load");
    let generous = powered(SchedKind::Ras, 823, Some(100_000.0));
    assert_eq!(generous.battery_depletions, 0, "a 100 kJ budget cannot drain in 14 frames");
    assert!(generous.battery_final_j.iter().all(|&j| j > 0.0));
}

/// The no-model run and the zero-watt-model run are the same simulation:
/// identical rows (the hooks fire but draw no RNG and integrate nothing),
/// and the mains-powered pi2b run only *observes* — it must not perturb a
/// single scheduling outcome relative to the unmetered twin.
#[test]
fn energy_accounting_is_observer_only() {
    let base = |seed: u64| {
        ScenarioBuilder::new()
            .scheduler(SchedKind::Wps)
            .trace(TraceSpec::Weighted(3))
            .frames(12)
            .seed(seed)
            .loss_rate(0.1)
            .crash_at(45.0, 2)
            .recover_at(140.0, 2)
    };
    for seed in [831u64, 832] {
        let plain = base(seed).build().run();
        let zero = base(seed).energy(EnergyModel::zero()).build().run();
        assert_eq!(
            format!("{plain:?}"),
            format!("{zero:?}"),
            "seed {seed}: zero-watt model must be bit-identical to no model"
        );
        let metered = base(seed).energy(EnergyModel::pi2b()).build().run();
        assert!(metered.energy_total_j > 0.0);
        // Everything the simulation *decides* is unchanged by metering.
        assert_eq!(metered.frames_completed, plain.frames_completed, "seed {seed}");
        assert_eq!(metered.lp_deadline_met(), plain.lp_deadline_met(), "seed {seed}");
        assert_eq!(metered.hp_completed, plain.hp_completed, "seed {seed}");
        assert_eq!(metered.lp_violations, plain.lp_violations, "seed {seed}");
        assert_eq!(metered.final_bandwidth_estimate_bps, plain.final_bandwidth_estimate_bps);
    }
}

/// Acceptance: under MMPP overload, opening the cloud tier strictly
/// raises deadlines met for every scheduler — the WAN spill valve must
/// buy real capacity, not just move placements around.
#[test]
fn cloud_tier_strictly_raises_deadline_met_under_overload() {
    let cfg = SystemConfig { seed: 29, ..SystemConfig::default() };
    let kinds = [SchedKind::Wps, SchedKind::Ras, SchedKind::Energy];
    let rows = experiments::cloud_burst_grid(&cfg, &kinds, 8.0).run();
    assert_eq!(rows.len(), 6);
    for pair in rows.chunks(2) {
        let (edge, cloud) = (&pair[0], &pair[1]);
        assert!(edge.label.ends_with("_edge") && cloud.label.ends_with("_cloud"));
        assert_eq!(edge.cloud_offloads, 0, "{}: edge twin must not touch the cloud", edge.label);
        assert!(cloud.cloud_offloads > 0, "{}: overload must spill to the WAN", cloud.label);
        assert!(
            cloud.lp_deadline_met() > edge.lp_deadline_met(),
            "{} vs {}: cloud tier must strictly raise deadline-met ({} vs {})",
            cloud.label,
            edge.label,
            cloud.lp_deadline_met(),
            edge.lp_deadline_met()
        );
    }
}

/// Acceptance: in the battery-constrained grid the energy-aware
/// scheduler — joule-scored placements plus the battery-scarcity
/// steering — buys more deadlines per kilojoule than either
/// deadline-only scheduler.
#[test]
fn energy_scheduler_wins_deadline_met_per_kilojoule() {
    let cfg = SystemConfig { seed: 31, ..SystemConfig::default() };
    let kinds = [SchedKind::Wps, SchedKind::Ras, SchedKind::Energy];
    let rows =
        experiments::energy_battery_grid(&cfg, &kinds, 6.0, 400.0, &EnergyModel::pi2b()).run();
    assert_eq!(rows.len(), 3);
    let per_kj: Vec<(String, f64)> =
        rows.iter().map(|m| (m.label.clone(), m.deadline_met_per_kj())).collect();
    let energy = per_kj.iter().find(|(l, _)| l.starts_with("ENERGY")).unwrap();
    for (label, v) in per_kj.iter().filter(|(l, _)| !l.starts_with("ENERGY")) {
        assert!(
            energy.1 > *v,
            "battery grid: ENERGY must beat {label} on deadlines/kJ ({:.3} vs {v:.3})",
            energy.1
        );
    }
}
