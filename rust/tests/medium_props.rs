//! Property tests for the fluid shared-medium model (`sim::netsim`):
//! randomized schedules of ≥1k operations against three invariants the
//! whole simulator leans on —
//!
//! 1. **capacity**: total bits drained never exceed link capacity ×
//!    elapsed time;
//! 2. **monotonicity**: `next_completion` predictions never move earlier
//!    as `now` advances (absent rate-changing mutations);
//! 3. **conservation**: an `add_flow`/`remove_flow` round-trip at one
//!    instant leaves every other flow's remaining bits untouched, and
//!    per-flow remaining bits only ever decrease.

use medge::sim::netsim::{FlowId, LossyMedium, Medium};
use medge::util::prop::forall;

#[test]
fn drained_bits_never_exceed_capacity_times_elapsed() {
    forall("medium capacity bound", 30, |rng| {
        let link = 10e6 + rng.gen_f64() * 40e6;
        let mut m = Medium::new(link, link * 0.8);
        let mut now = 0u64;
        // Bits currently owed to live flows if nothing had drained:
        // added minus what removals handed back.
        let mut budget = 0.0f64;
        let mut live: Vec<FlowId> = Vec::new();
        let mut next_id: FlowId = 1;
        for _ in 0..1500 {
            now += rng.gen_range(50_000);
            match rng.index(6) {
                0 | 1 => {
                    let bytes = 1_000 + rng.gen_range(2_000_000);
                    m.add_flow(now, next_id, bytes);
                    budget += bytes as f64 * 8.0;
                    live.push(next_id);
                    next_id += 1;
                }
                2 => {
                    if !live.is_empty() {
                        let id = live.swap_remove(rng.index(live.len()));
                        let rem = m.remaining_bits(now, id).expect("live flow tracked");
                        assert!(m.remove_flow(now, id));
                        budget -= rem; // unsent bits leave with the flow
                    }
                }
                3 => m.set_background(now, rng.index(2) == 0),
                4 => {
                    if let Some((t, id)) = m.next_completion(now) {
                        if m.complete_flow(t, id) {
                            now = t;
                            live.retain(|&f| f != id);
                            // Completion tolerance: the popped flow may
                            // carry a sliver of undrained bits.
                            budget -= m.per_flow_bps() / 1e5 + 1.0;
                        }
                    }
                }
                _ => {
                    let _ = m.next_completion(now);
                }
            }
            let remaining = m.total_remaining_bits(now);
            let drained = budget - remaining;
            let cap = link * (now as f64 / 1e6);
            if drained > cap * 1.000_001 + 1e5 {
                return Err(format!(
                    "drained {drained:.0} bits > capacity bound {cap:.0} at t={now}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn next_completion_is_monotone_in_now() {
    forall("next_completion monotone", 60, |rng| {
        let mut m = Medium::new(20e6, 0.0);
        let mut now = 0u64;
        for id in 1..=(1 + rng.gen_range(6)) {
            m.add_flow(now, id, 10_000 + rng.gen_range(500_000));
        }
        let Some((mut prev, _)) = m.next_completion(now) else {
            return Err("seeded flows must predict a completion".into());
        };
        for _ in 0..60 {
            now += 1 + rng.gen_range(30_000);
            match m.next_completion(now) {
                Some((t, _)) => {
                    if t < now {
                        return Err(format!("completion {t} predicted before now {now}"));
                    }
                    // Without rate changes the predicted finish is a fixed
                    // point; integer rounding and float drift may wiggle it
                    // by a few µs but it must never move meaningfully
                    // earlier as time advances.
                    if t + 2 < prev.max(now) {
                        return Err(format!(
                            "prediction moved earlier: {prev} -> {t} at now={now}"
                        ));
                    }
                    prev = t;
                }
                None => return Err("flows vanished without removal".into()),
            }
        }
        Ok(())
    });
}

#[test]
fn add_remove_roundtrip_conserves_other_flows() {
    forall("add/remove round-trip conserves", 40, |rng| {
        let mut m = Medium::new(30e6, 10e6);
        let mut now = 0u64;
        let resident: Vec<FlowId> = (1..=3).collect();
        for &id in &resident {
            m.add_flow(now, id, 500_000 + rng.gen_range(500_000));
        }
        for step in 0..400u64 {
            now += rng.gen_range(10_000);
            if rng.index(4) == 0 {
                m.set_background(now, rng.index(2) == 0);
            }
            let before: Vec<f64> = resident
                .iter()
                .map(|&id| m.remaining_bits(now, id).unwrap_or(0.0))
                .collect();
            // Round-trip a transient flow at a single instant: no time
            // passes, so nothing may drain and nothing may be refunded.
            let transient = 1_000 + step;
            m.add_flow(now, transient, 1 + rng.gen_range(2_000_000));
            assert!(m.remove_flow(now, transient));
            let after: Vec<f64> = resident
                .iter()
                .map(|&id| m.remaining_bits(now, id).unwrap_or(0.0))
                .collect();
            for (i, (b, a)) in before.iter().zip(&after).enumerate() {
                if (b - a).abs() > 1e-6 {
                    return Err(format!(
                        "flow {} changed across round-trip at t={now}: {b} -> {a}",
                        resident[i]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn per_flow_remaining_bits_are_monotone_decreasing() {
    forall("per-flow monotone drain", 40, |rng| {
        let mut m = Medium::new(25e6, 0.0);
        let mut now = 0u64;
        for id in 1..=4 {
            m.add_flow(now, id, 2_000_000);
        }
        let mut last: Vec<f64> = (1..=4).map(|id| m.remaining_bits(now, id).unwrap()).collect();
        for _ in 0..300 {
            now += 1 + rng.gen_range(40_000);
            if rng.index(5) == 0 {
                m.set_background(now, rng.index(2) == 0);
            }
            for (i, id) in (1..=4u64).enumerate() {
                if let Some(rem) = m.remaining_bits(now, id) {
                    if rem > last[i] + 1e-9 {
                        return Err(format!("flow {id} gained bits: {} -> {rem}", last[i]));
                    }
                    if rem < 0.0 {
                        return Err(format!("flow {id} went negative: {rem}"));
                    }
                    last[i] = rem;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn lossy_medium_upholds_capacity_bound_on_inflated_flows() {
    // The retransmission inflation adds bits *before* the fluid model
    // sees them, so the capacity bound must hold against the inflated
    // totals too (inflation changes demand, never physics).
    forall("lossy capacity bound", 20, |rng| {
        let link = 20e6;
        let mut m = LossyMedium::new(Medium::new(link, 0.0), 0.3, 0.0, rng.next_u64());
        let mut now = 0u64;
        let mut budget = 0.0f64;
        for id in 1..=60u64 {
            now += rng.gen_range(200_000);
            m.add_flow(now, id, 50_000 + rng.gen_range(1_000_000));
            // Account the *inflated* size the medium actually queued.
            budget += m.remaining_bits(now, id).expect("flow just added");
            let remaining = m.total_remaining_bits(now);
            let drained = budget - remaining;
            let cap = link * (now as f64 / 1e6);
            if drained > cap * 1.000_001 + 1e5 {
                return Err(format!("lossy medium drained {drained:.0} > {cap:.0}"));
            }
        }
        Ok(())
    });
}
