//! Property-based invariants (in-tree `forall` driver): the data
//! structures and schedulers hold their guarantees under randomized
//! workloads.

use medge::config::SystemConfig;
use medge::coordinator::netlink::{CommTask, DiscretisedLink};
use medge::coordinator::ras::ResourceAvailabilityList;
use medge::coordinator::scheduler::ras_sched::RasScheduler;
use medge::coordinator::scheduler::wps::WpsScheduler;
use medge::coordinator::scheduler::{LpOutcome, Scheduler, SchedulerCompat};
use medge::coordinator::task::Task;
use medge::util::prop::forall;
use medge::util::Rng;

#[test]
fn availability_list_invariants_under_random_writes() {
    forall("ras list random writes", 300, |rng| {
        let tracks = 1 + rng.index(4);
        let min_dur = 100 + rng.gen_range(5_000);
        let mut list = ResourceAvailabilityList::fully_available(2, min_dur, tracks, 0);
        for _ in 0..rng.index(40) {
            let s1 = rng.gen_range(1_000_000);
            let s2 = s1 + 1 + rng.gen_range(200_000);
            let cores = 1 + rng.gen_range(4) as u32;
            list.write(s1, s2, cores);
        }
        list.check_invariants()
    });
}

#[test]
fn availability_windows_shrink_monotonically() {
    // A write never *creates* availability: any slot that is containable
    // after a write was containable before it.
    forall("writes only remove availability", 200, |rng| {
        let mut list = ResourceAvailabilityList::fully_available(2, 1_000, 2, 0);
        for _ in 0..rng.index(20) {
            let s1 = rng.gen_range(500_000);
            let s2 = s1 + 1 + rng.gen_range(100_000);
            let before = list.clone();
            list.write(s1, s2, 2);
            for _ in 0..10 {
                let q1 = rng.gen_range(700_000);
                let q2 = q1 + 1_000 + rng.gen_range(50_000);
                if list.query_containment(q1, q2).is_some()
                    && before.query_containment(q1, q2).is_none()
                {
                    return Err(format!("write created availability at [{q1}, {q2})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn link_index_always_lands_in_covering_bucket() {
    forall("link index containment", 300, |rng| {
        let unit = 1 + rng.gen_range(10_000);
        let base = 1 + rng.index(32);
        let exp = rng.index(10);
        let origin = rng.gen_range(1_000_000);
        let link = DiscretisedLink::build(origin, unit, base, exp);
        link.check_invariants()?;
        for _ in 0..50 {
            let t = link.t_r + rng.gen_range(link.horizon() - link.t_r);
            match link.index(t) {
                Some(i) => {
                    let b = &link.buckets[i];
                    if !(b.t1 <= t && t < b.t2) {
                        return Err(format!("t={t} landed in bucket {i} [{}, {})", b.t1, b.t2));
                    }
                }
                None => return Err(format!("t={t} inside horizon had no bucket")),
            }
        }
        Ok(())
    });
}

#[test]
fn link_capacity_never_exceeded_and_cascade_preserves_future_items() {
    forall("link placement + cascade", 200, |rng| {
        let mut link = DiscretisedLink::build(0, 1_000, 8, 4);
        let mut placed = 0u64;
        for task in 0..rng.gen_range(40) {
            let t_p = rng.gen_range(link.horizon());
            if link
                .place(t_p, link.horizon(), CommTask { task, from: 0, to: 1, planned_start: t_p })
                .is_some()
            {
                placed += 1;
            }
        }
        link.check_invariants()?;
        let now = rng.gen_range(8_000);
        let (fresh, dropped) = link.rebuild(now, 2_000);
        fresh.check_invariants()?;
        if fresh.pending() + dropped != placed as usize {
            return Err(format!(
                "cascade lost items: pending {} + dropped {dropped} != placed {placed}",
                fresh.pending()
            ));
        }
        Ok(())
    });
}

fn random_requests(rng: &mut Rng, sched: &mut dyn Scheduler, cfg: &SystemConfig) {
    let mut id = 1u64;
    for round in 0..rng.index(12) {
        let now = round as u64 * rng.gen_range(4_000_000);
        let source = rng.index(cfg.n_devices);
        if rng.gen_f64() < 0.5 {
            let t = Task::high(id, id, source, now, cfg);
            id += 1;
            let _ = sched.schedule_high(now, &t);
        } else {
            let n = 1 + rng.index(4);
            let deadline = now + cfg.frame_period();
            let tasks: Vec<Task> = (0..n)
                .map(|i| Task::low(id + i as u64, id, source, now, deadline, cfg))
                .collect();
            id += n as u64;
            if let LpOutcome::Allocated { allocs, .. } = sched.schedule_low(now, &tasks, false) {
                // Randomly complete some tasks to exercise removal.
                for a in allocs {
                    if rng.gen_f64() < 0.3 {
                        sched.on_complete(a.end, a.task);
                    }
                }
            }
        }
    }
}

#[test]
fn schedulers_never_oversubscribe_devices() {
    forall("no oversubscription", 120, |rng| {
        let cfg = SystemConfig { seed: rng.next_u64(), ..Default::default() };
        let mut schedulers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps)),
            Box::new(WpsScheduler::new(&cfg, 0, cfg.link_bps)),
        ];
        for sched in &mut schedulers {
            random_requests(rng, sched.as_mut(), &cfg);
            for d in 0..cfg.n_devices {
                for t in (0..60_000_000u64).step_by(1_000_000) {
                    let (peak, _) = sched.state().peak_usage(d, t, t + 1_000_000);
                    if peak > cfg.cores_per_device {
                        return Err(format!(
                            "{} oversubscribed device {d} at t={t}: {peak} cores",
                            sched.name()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn ras_internal_invariants_hold_under_random_load() {
    forall("ras invariants", 100, |rng| {
        let cfg = SystemConfig { seed: rng.next_u64(), ..Default::default() };
        let mut s = RasScheduler::new(&cfg, 0, cfg.link_bps);
        random_requests(rng, &mut s, &cfg);
        let _ = s.on_bandwidth_update(rng.gen_range(60_000_000), cfg.link_bps * (0.5 + rng.gen_f64()));
        random_requests(rng, &mut s, &cfg);
        s.check_invariants()
    });
}

#[test]
fn allocations_always_respect_deadlines_at_decision_time() {
    forall("deadline-respecting allocations", 100, |rng| {
        let cfg = SystemConfig { seed: rng.next_u64(), ..Default::default() };
        let mut schedulers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps)),
            Box::new(WpsScheduler::new(&cfg, 0, cfg.link_bps)),
        ];
        for sched in &mut schedulers {
            let now = rng.gen_range(10_000_000);
            let deadline = now + cfg.frame_period();
            let tasks: Vec<Task> =
                (0..3).map(|i| Task::low(i + 1, 1, 0, now, deadline, &cfg)).collect();
            if let LpOutcome::Allocated { allocs, .. } = sched.schedule_low(now, &tasks, false) {
                for a in &allocs {
                    if a.end > a.deadline {
                        return Err(format!("{}: allocation ends past deadline", sched.name()));
                    }
                    if a.start < now {
                        return Err(format!("{}: allocation starts in the past", sched.name()));
                    }
                }
            }
        }
        Ok(())
    });
}
