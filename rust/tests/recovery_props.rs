//! Recovery-policy property suite (PR 8): under *randomized* fault
//! schedules — crash and partition windows, packet and probe loss, with
//! the failure detector, offload retries, hedged duplicates, and
//! bandwidth staleness all armed — the engine's conservation invariants
//! must close on every sampled case:
//!
//! - every offered task reaches exactly one terminal counter
//!   (completed, violated, or lost — no leaks, no double credit);
//! - hedge pairs settle at most once;
//! - the task slab is empty after the drain, even when a partition
//!   never heals.
//!
//! The driver is the in-tree [`medge::util::prop::forall`] (proptest is
//! unavailable offline); failures print the case seed for exact replay.

use medge::scenario::{Scenario, ScenarioBuilder, SchedKind};
use medge::util::prop::forall;
use medge::util::rng::Rng;
use medge::workload::trace::TraceSpec;

/// Sample one randomized chaos scenario from `rng`: a fault window per
/// non-coordinator device (crash, partition, or nothing — sometimes
/// never healing), random loss rates, and every robustness knob drawn
/// from its live range.
fn sampled(rng: &mut Rng, kind: SchedKind) -> Scenario {
    let frames = 10 + rng.index(8);
    let cfg = medge::config::SystemConfig { seed: rng.next_u64(), ..Default::default() };
    let total_s = frames as f64 * cfg.frame_period_s;
    let n_devices = cfg.n_devices;
    let mut b = ScenarioBuilder::new()
        .config(cfg)
        .scheduler(kind)
        .trace(TraceSpec::Weighted(1 + rng.index(4) as u8))
        .frames(frames)
        .named("prop_chaos")
        .loss_rate(rng.gen_f64() * 0.15)
        .probe_loss(rng.gen_f64() * 0.5)
        .detector(1 + rng.index(3) as u32, 1 + rng.index(2) as u32)
        .offload_timeout(0.1 + rng.gen_f64(), 1 + rng.index(3) as u32)
        .hedge(0.1 + rng.gen_f64())
        .bw_stale_after(1 + rng.index(3) as u32);
    for device in 1..n_devices {
        let start = total_s * (0.1 + rng.gen_f64() * 0.6);
        let end = (start + total_s * (0.05 + rng.gen_f64() * 0.4)).min(total_s * 0.95);
        match rng.index(5) {
            0 => b = b.crash_at(start, device).recover_at(end, device),
            1 => b = b.partition_at(start, device).heal_at(end, device),
            2 => b = b.crash_at(start, device), // never recovers
            3 => b = b.partition_at(start, device), // never heals
            _ => {}
        }
    }
    b.build()
}

/// Drain one sampled scenario and check every conservation invariant,
/// returning a replayable description of the first violation.
fn check(rng: &mut Rng, kind: SchedKind) -> Result<(), String> {
    let s = sampled(rng, kind);
    let mut eng = s.engine();
    let m = eng.drain().clone();
    let fail = |what: &str| Err(format!("{what} violated\n{m:?}"));
    if eng.live_tasks() != 0 {
        return fail("empty slab after drain");
    }
    if m.hp_generated != m.hp_allocated_no_preempt + m.hp_allocated_with_preempt + m.hp_rejected {
        return fail("hp offered == allocated + rejected");
    }
    if m.lp_generated != m.lp_completed_total() + m.lp_violations + m.lp_lost {
        return fail("lp offered == completed + violated + lost");
    }
    if m.two_core_allocs + m.four_core_allocs + m.cloud_offloads
        != m.lp_allocated_initial + m.lp_realloc_success
    {
        return fail("core mix == successful placements");
    }
    if m.hedges_won + m.hedges_wasted > m.hedges_launched {
        return fail("hedge pairs settle at most once");
    }
    if m.devices_cleared > m.devices_suspected {
        return fail("clears need prior suspicions");
    }
    if m.offloaded_completed > m.offloaded_total {
        return fail("offload completions bounded by placements");
    }
    if m.frames_completed > m.frames_total {
        return fail("frame completions bounded");
    }
    Ok(())
}

#[test]
fn conservation_holds_under_random_faults_wps() {
    forall("chaos conservation / wps", 40, |rng| check(rng, SchedKind::Wps));
}

#[test]
fn conservation_holds_under_random_faults_ras() {
    forall("chaos conservation / ras", 40, |rng| check(rng, SchedKind::Ras));
}

#[test]
fn conservation_holds_under_random_faults_multi() {
    forall("chaos conservation / multi", 40, |rng| check(rng, SchedKind::Multi));
}

#[test]
fn robustness_machinery_is_not_vacuous() {
    // The invariant sweep above means nothing if the sampled schedules
    // never exercise the machinery: across a modest sample, suspicion,
    // partition stalls, and the recovery policy must each fire somewhere.
    let mut rng = Rng::seed_from_u64(0x524f_4255); // "ROBU"
    let (mut suspected, mut stalled, mut recovered) = (false, false, false);
    for _ in 0..25 {
        let s = sampled(&mut rng, SchedKind::Ras);
        let m = s.run();
        suspected |= m.devices_suspected > 0;
        stalled |= m.partition_stalled_flows + m.partition_held_results > 0;
        recovered |= m.retries + m.hedges_launched > 0;
        if suspected && stalled && recovered {
            return;
        }
    }
    panic!(
        "vacuous sample: suspected={suspected} stalled={stalled} recovered={recovered}"
    );
}
