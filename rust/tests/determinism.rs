//! Determinism property suite: the same `Sweep` grid with the same seeds
//! must produce identical rows at any worker-thread count — **including
//! under a `FaultPlan`**. Faults draw only from RNG streams derived from
//! the scenario seed (the fault-schedule generator, the lossy medium's
//! loss sampler), never from ambient randomness, so a crashing, lossy,
//! probe-dropping run replays bit for bit.

use medge::fault::FaultPlan;
use medge::scenario::{Scenario, ScenarioBuilder, SchedKind, Sweep};
use medge::workload::gen::{ArrivalProcess, Catalog, GenSpec, Workload};
use medge::workload::trace::TraceSpec;

/// A scenario exercising every nondeterminism-prone path: random faults,
/// packet loss, probe loss, churn, and a congestion regime change.
fn faulted(kind: SchedKind, load: u8, seed: u64) -> Scenario {
    ScenarioBuilder::new()
        .scheduler(kind)
        .trace(TraceSpec::Weighted(load))
        .frames(12)
        .seed(seed)
        .leave_at(80.0, 1)
        .join_at(150.0, 1)
        .congestion_at(60.0, 36e6, 0.5)
        .crash_at(40.0, 0)
        .recover_at(120.0, 0)
        .loss_rate(0.1)
        .probe_loss(0.3)
        .random_faults(200.0, 40.0)
        .named(format!("{}_{}_s{}", kind.label(), load, seed))
        .build()
}

fn grid() -> Sweep {
    let mut sweep = Sweep::new();
    for (i, kind) in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi].into_iter().enumerate() {
        for load in [2u8, 4] {
            sweep = sweep.add(faulted(kind, load, 100 + i as u64));
        }
    }
    sweep
}

fn rows_debug(sweep: &Sweep) -> Vec<String> {
    sweep.run().iter().map(|m| format!("{m:?}")).collect()
}

/// Generative workloads across every scheduler and arrival family, with
/// an admission cap and a mid-run crash thrown in: arrival plans are
/// compiled from the scenario seed before the run starts, so the rows
/// must be identical across worker-thread counts and repeated runs.
fn gen_grid() -> Sweep {
    let cfg = medge::config::SystemConfig::default();
    let procs = [
        ArrivalProcess::Poisson { rate_per_min: 10.0 },
        ArrivalProcess::Mmpp {
            on_rate_per_min: 30.0,
            off_rate_per_min: 1.0,
            mean_on_s: 30.0,
            mean_off_s: 60.0,
        },
        ArrivalProcess::Diurnal { base_rate_per_min: 8.0, amplitude: 0.8, period_s: 240.0 },
        ArrivalProcess::ClosedLoop { users: 5, think_s: 20.0 },
    ];
    let mut sweep = Sweep::new();
    for (i, kind) in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi].into_iter().enumerate() {
        for (j, proc) in procs.iter().enumerate() {
            sweep = sweep.add(
                ScenarioBuilder::new()
                    .scheduler(kind)
                    .workload(Workload::Generative(GenSpec {
                        arrivals: proc.clone(),
                        catalog: Catalog::edge_serving(&cfg),
                        admission_cap: 16,
                    }))
                    .minutes(8.0)
                    .seed(300 + (i * procs.len() + j) as u64)
                    .crash_at(120.0, 1)
                    .recover_at(240.0, 1)
                    .loss_rate(0.05)
                    .named(format!("{}_{}", kind.label(), proc.label()))
                    .build(),
            );
        }
    }
    sweep
}

/// The degradation axis: 3 schedulers × 2 ladder depths (1 = the
/// no-degradation twin, 3 = the full stage-3 family) under bursty MMPP
/// pressure with a mid-run crash and a lossy link. Degraded placements
/// re-spec tasks and re-enter the requeue/re-offer machinery, so this
/// grid exercises every ladder path the engine has — and must still be
/// identical across worker-thread counts and repeated runs.
fn accuracy_grid() -> Sweep {
    let cfg = medge::config::SystemConfig::default();
    let mut sweep = Sweep::new();
    for (i, kind) in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi].into_iter().enumerate() {
        for (j, depth) in [1usize, 3].into_iter().enumerate() {
            sweep = sweep.add(
                ScenarioBuilder::new()
                    .scheduler(kind)
                    .workload(Workload::generative(
                        medge::experiments::frontier_arrivals(30.0),
                        medge::experiments::frontier_catalog(&cfg, depth),
                    ))
                    .minutes(8.0)
                    .seed(500 + (i * 2 + j) as u64)
                    .crash_at(120.0, 1)
                    .recover_at(240.0, 1)
                    .loss_rate(0.05)
                    .probe_loss(0.2)
                    .named(format!("{}_d{}", kind.label(), depth))
                    .build(),
            );
        }
    }
    sweep
}

/// The anytime axis (PR 10): all four LP policies × truncation {full,
/// cut} on the staged stage-3 family under bursty MMPP pressure, with a
/// mid-run crash and a lossy link in every cell. Stage-boundary chains,
/// pressure surveys, and truncated finishes all ride the seed-derived
/// streams — and the controller itself draws no RNG — so the rows must
/// be identical across worker-thread counts and repeats.
fn anytime_grid() -> Sweep {
    let cfg = medge::config::SystemConfig::default();
    let kinds = [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi, SchedKind::Greedy];
    let mut sweep = Sweep::new();
    for (i, kind) in kinds.into_iter().enumerate() {
        for (j, cut) in [false, true].into_iter().enumerate() {
            let mut b = ScenarioBuilder::new()
                .scheduler(kind)
                .workload(Workload::generative(
                    medge::experiments::frontier_arrivals(30.0),
                    medge::experiments::anytime_catalog(&cfg),
                ))
                .minutes(8.0)
                .seed(1100 + (i * 2 + j) as u64)
                .crash_at(120.0, 1)
                .recover_at(240.0, 1)
                .loss_rate(0.05)
                .probe_loss(0.2)
                .named(format!("{}_{}", kind.label(), if cut { "cut" } else { "full" }));
            if cut {
                b = b.pressure(0.5, 8);
            }
            sweep = sweep.add(b.build());
        }
    }
    sweep
}

/// The energy & cloud-tier axis: {WPS, RAS, ENERGY} × {battery-constrained
/// conveyor, cloud-burst MMPP overload}, with a crash and a lossy link in
/// every cell. Battery depletion re-enters the crash/re-offer machinery and
/// the cloud path adds a second (WAN) flow table plus passive bandwidth
/// feedback — none of which may draw outside the seed-derived streams, so
/// the rows must be identical across worker-thread counts and repeats.
fn energy_grid() -> Sweep {
    let cfg = medge::config::SystemConfig::default();
    let kinds = [SchedKind::Wps, SchedKind::Ras, SchedKind::Energy];
    let mut sweep = Sweep::new();
    for (i, kind) in kinds.into_iter().enumerate() {
        // Battery-constrained conveyor cell: tight budget, cloud reachable.
        sweep = sweep.add(
            ScenarioBuilder::new()
                .scheduler(kind)
                .trace(TraceSpec::Weighted(4))
                .frames(12)
                .seed(700 + i as u64)
                .energy(medge::energy::EnergyModel::pi2b())
                .battery_j(300.0)
                .cloud(20e6, 40.0)
                .crash_at(40.0, 0)
                .recover_at(120.0, 0)
                .loss_rate(0.1)
                .probe_loss(0.2)
                .named(format!("{}_bat", kind.label()))
                .build(),
        );
        // Cloud-burst cell: MMPP overload spilling onto the WAN tier.
        sweep = sweep.add(
            ScenarioBuilder::new()
                .scheduler(kind)
                .workload(Workload::Generative(GenSpec {
                    arrivals: ArrivalProcess::Mmpp {
                        on_rate_per_min: 36.0,
                        off_rate_per_min: 1.0,
                        mean_on_s: 60.0,
                        mean_off_s: 60.0,
                    },
                    catalog: Catalog::edge_serving(&cfg),
                    admission_cap: 0,
                }))
                .minutes(8.0)
                .seed(710 + i as u64)
                .energy(medge::energy::EnergyModel::pi2b())
                .cloud(20e6, 40.0)
                .crash_at(120.0, 1)
                .recover_at(240.0, 1)
                .loss_rate(0.05)
                .named(format!("{}_burst", kind.label()))
                .build(),
        );
    }
    sweep
}

/// The robustness axis (PR 8): every scheduler with the failure
/// detector, a partition window, a crash window, offload retries, hedged
/// duplicates, and bandwidth staleness all armed at once, on a lossy,
/// probe-dropping link. Detection, stall/heal, timeout rescheduling, and
/// hedge settlement all ride the seed-derived streams, so the rows must
/// be identical across worker-thread counts and repeats.
fn chaos_grid() -> Sweep {
    let mut sweep = Sweep::new();
    for (i, kind) in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi].into_iter().enumerate() {
        sweep = sweep.add(
            ScenarioBuilder::new()
                .scheduler(kind)
                .trace(TraceSpec::Weighted(4))
                .frames(16)
                .seed(900 + i as u64)
                .detector(2, 2)
                .offload_timeout(0.4, 2)
                .hedge(0.4)
                .bw_stale_after(2)
                .loss_rate(0.08)
                .probe_loss(0.3)
                .crash_at(60.0, 2)
                .recover_at(150.0, 2)
                .partition_at(90.0, 1)
                .heal_at(180.0, 1)
                .named(format!("{}_chaos", kind.label()))
                .build(),
        );
    }
    sweep
}

#[test]
fn chaos_grid_identical_across_thread_counts() {
    let g = chaos_grid();
    let seq = rows_debug(&g.clone().threads(1));
    let par4 = rows_debug(&g.clone().threads(4));
    let par2 = rows_debug(&g.threads(2));
    assert_eq!(seq.len(), 3);
    for (i, row) in seq.iter().enumerate() {
        assert_eq!(row, &par4[i], "chaos row {i} differs between --threads 1 and --threads 4");
        assert_eq!(row, &par2[i], "chaos row {i} differs between --threads 1 and --threads 2");
    }
}

#[test]
fn chaos_grid_identical_across_repeated_runs() {
    let g = chaos_grid().threads(4);
    assert_eq!(rows_debug(&g), rows_debug(&g), "re-running the chaos sweep must not drift");
}

#[test]
fn chaos_grid_actually_fires_the_robustness_machinery() {
    // Guard against a silently inert axis: the detector must suspect,
    // the partition must stall work, and the recovery policy (retry or
    // hedge) must fire somewhere — while the conservation identity
    // closes in every row.
    let rows = chaos_grid().threads(2).run();
    assert!(rows.iter().any(|m| m.devices_suspected > 0), "detector never suspected anyone");
    assert!(
        rows.iter().any(|m| m.retries + m.hedges_launched > 0),
        "recovery policy never fired"
    );
    for m in &rows {
        assert_eq!(m.partitions_started, 1, "{}: partition window missing", m.label);
        assert_eq!(m.partitions_healed, 1, "{}: heal missing", m.label);
        assert_eq!(
            m.lp_generated,
            m.lp_completed_total() + m.lp_violations + m.lp_lost,
            "{}: lp conservation",
            m.label
        );
        assert!(m.hedges_won + m.hedges_wasted <= m.hedges_launched, "{}: hedge settle", m.label);
    }
}

#[test]
fn energy_grid_identical_across_thread_counts() {
    let g = energy_grid();
    let seq = rows_debug(&g.clone().threads(1));
    let par4 = rows_debug(&g.clone().threads(4));
    let par2 = rows_debug(&g.threads(2));
    assert_eq!(seq.len(), 6);
    for (i, row) in seq.iter().enumerate() {
        assert_eq!(row, &par4[i], "energy row {i} differs between --threads 1 and --threads 4");
        assert_eq!(row, &par2[i], "energy row {i} differs between --threads 1 and --threads 2");
    }
}

#[test]
fn energy_grid_identical_across_repeated_runs() {
    let g = energy_grid().threads(4);
    assert_eq!(rows_debug(&g), rows_debug(&g), "re-running the energy sweep must not drift");
}

#[test]
fn energy_grid_actually_drains_and_offloads() {
    // Guard against a silently inert axis: somewhere in the grid a
    // battery must deplete, the cloud must take work, and every cell
    // must integrate joules and keep the generalized placement identity.
    let rows = energy_grid().threads(2).run();
    assert!(
        rows.iter().any(|m| m.battery_depletions > 0),
        "a 300 J budget under weighted-4 load must deplete somewhere"
    );
    assert!(
        rows.iter().any(|m| m.cloud_offloads > 0),
        "MMPP overload with a WAN tier must offload somewhere"
    );
    for m in &rows {
        assert!(m.energy_total_j > 0.0, "{}: power model must integrate", m.label);
        assert!(
            m.cloud_completions <= m.cloud_offloads,
            "{}: cloud deliveries cannot exceed cloud placements",
            m.label
        );
        assert_eq!(
            m.two_core_allocs + m.four_core_allocs + m.cloud_offloads,
            m.lp_allocated_initial + m.lp_realloc_success,
            "{}: three-tier placement identity",
            m.label
        );
    }
}

#[test]
fn anytime_grid_identical_across_thread_counts() {
    let g = anytime_grid();
    let seq = rows_debug(&g.clone().threads(1));
    let par4 = rows_debug(&g.clone().threads(4));
    let par2 = rows_debug(&g.threads(2));
    assert_eq!(seq.len(), 8);
    for (i, row) in seq.iter().enumerate() {
        assert_eq!(row, &par4[i], "anytime row {i} differs between --threads 1 and --threads 4");
        assert_eq!(row, &par2[i], "anytime row {i} differs between --threads 1 and --threads 2");
    }
}

#[test]
fn anytime_grid_identical_across_repeated_runs() {
    let g = anytime_grid().threads(4);
    assert_eq!(rows_debug(&g), rows_debug(&g), "re-running the anytime sweep must not drift");
}

#[test]
fn anytime_grid_actually_truncates_and_keeps_identities() {
    // Guard against a silently inert controller: somewhere in the cut
    // rows a truncation must actually land, full rows must never
    // truncate, and the accounting identities must close through the
    // crash window in every cell.
    let rows = anytime_grid().threads(2).run();
    let mut any_truncated = false;
    for m in &rows {
        if m.label.ends_with("_cut") {
            any_truncated |= m.truncated_completions > 0;
        } else {
            assert_eq!(m.truncated_completions, 0, "{}: full row truncated", m.label);
            assert_eq!(m.pressure_events, 0, "{}: full row surveyed", m.label);
            assert_eq!(m.pressure_cuts, 0, "{}: full row armed cuts", m.label);
        }
        assert!(
            m.stages_skipped >= m.truncated_completions,
            "{}: each truncation skips at least one stage",
            m.label
        );
        assert_eq!(
            m.rung_completions.iter().sum::<u64>(),
            m.lp_deadline_met(),
            "{}: per-rung completion identity (truncated finishes still bank their rung)",
            m.label
        );
        assert_eq!(
            m.lp_generated,
            m.lp_completed_total() + m.lp_violations + m.lp_lost,
            "{}: lp conservation",
            m.label
        );
    }
    assert!(any_truncated, "the cut rows should truncate under MMPP pressure");
}

#[test]
fn accuracy_grid_identical_across_thread_counts() {
    let g = accuracy_grid();
    let seq = rows_debug(&g.clone().threads(1));
    let par4 = rows_debug(&g.clone().threads(4));
    let par2 = rows_debug(&g.threads(2));
    assert_eq!(seq.len(), 6);
    for (i, row) in seq.iter().enumerate() {
        assert_eq!(row, &par4[i], "accuracy row {i} differs between --threads 1 and --threads 4");
        assert_eq!(row, &par2[i], "accuracy row {i} differs between --threads 1 and --threads 2");
    }
}

#[test]
fn accuracy_grid_identical_across_repeated_runs() {
    let g = accuracy_grid().threads(4);
    assert_eq!(rows_debug(&g), rows_debug(&g), "re-running the accuracy sweep must not drift");
}

#[test]
fn accuracy_grid_actually_degrades_and_keeps_identities() {
    // Guard against a silently inert ladder: somewhere in the deep rows
    // degradation must actually fire, depth-1 twins must never degrade,
    // and the accounting identities must close through the crash window.
    let rows = accuracy_grid().threads(2).run();
    let mut any_degraded = false;
    for m in &rows {
        let deep = m.label.ends_with("_d3");
        if deep {
            any_degraded |= m.degraded_completions > 0;
        } else {
            assert_eq!(m.degraded_completions, 0, "{}: depth-1 twin degraded", m.label);
            assert_eq!(m.degraded_placements, 0, "{}: depth-1 twin degraded", m.label);
        }
        assert_eq!(
            m.rung_completions.iter().sum::<u64>(),
            m.lp_deadline_met(),
            "{}: per-rung completion identity",
            m.label
        );
        // Offered load still closes through degradation + the crash.
        assert_eq!(
            m.offered_tasks,
            m.hp_generated + m.lp_generated + m.admission_dropped + m.offline_dropped,
            "{}: offered-load identity",
            m.label
        );
    }
    assert!(any_degraded, "the deep rows should degrade under MMPP pressure");
}

#[test]
fn loadgen_grid_identical_across_thread_counts() {
    let g = gen_grid();
    let seq = rows_debug(&g.clone().threads(1));
    let par4 = rows_debug(&g.clone().threads(4));
    let par2 = rows_debug(&g.threads(2));
    assert_eq!(seq.len(), 12);
    for (i, row) in seq.iter().enumerate() {
        assert_eq!(row, &par4[i], "gen row {i} differs between --threads 1 and --threads 4");
        assert_eq!(row, &par2[i], "gen row {i} differs between --threads 1 and --threads 2");
    }
}

#[test]
fn loadgen_grid_identical_across_repeated_runs() {
    let g = gen_grid().threads(4);
    assert_eq!(rows_debug(&g), rows_debug(&g), "re-running the loadgen sweep must not drift");
}

#[test]
fn loadgen_grid_actually_generates_load() {
    // Guard against a silently-empty plan: every row must have fired
    // arrivals, and the bursty rows must have seen admission pressure
    // somewhere in the grid.
    let rows = gen_grid().threads(2).run();
    assert!(rows.iter().all(|m| m.gen_arrivals > 0), "a generative row fired no arrivals");
    assert!(rows.iter().all(|m| m.offered_tasks > 0));
    assert!(
        rows.iter().any(|m| m.admission_dropped > 0),
        "a capped bursty grid should hit admission somewhere"
    );
    for m in &rows {
        // Offered load closes even through the crash outage: every
        // planned arrival is offered, then generated or dropped (cap or
        // offline source) — nothing vanishes.
        assert_eq!(
            m.offered_tasks,
            m.hp_generated + m.lp_generated + m.admission_dropped + m.offline_dropped,
            "{}: offered-load identity",
            m.label
        );
    }
}

#[test]
fn fault_grid_identical_across_thread_counts() {
    let g = grid();
    let seq = rows_debug(&g.clone().threads(1));
    let par4 = rows_debug(&g.clone().threads(4));
    let par2 = rows_debug(&g.threads(2));
    assert_eq!(seq.len(), 6);
    for (i, row) in seq.iter().enumerate() {
        assert_eq!(row, &par4[i], "row {i} differs between --threads 1 and --threads 4");
        assert_eq!(row, &par2[i], "row {i} differs between --threads 1 and --threads 2");
    }
}

#[test]
fn fault_grid_identical_across_repeated_runs() {
    let g = grid().threads(4);
    assert_eq!(rows_debug(&g), rows_debug(&g), "re-running the same sweep must not drift");
}

#[test]
fn fault_runs_actually_inject_faults() {
    // Guard against the suite silently testing a no-op plan: the grid's
    // scenarios must exhibit crashes, loss, and probe loss somewhere.
    let rows = grid().threads(2).run();
    assert!(rows.iter().any(|m| m.device_crashes > 0), "no crashes injected");
    assert!(rows.iter().any(|m| m.retransmitted_mbits > 0.0), "no loss injected");
    assert!(rows.iter().any(|m| m.probe_pings_lost > 0), "no probe loss injected");
}

#[test]
fn faulted_run_queue_occupancy_tracks_live_work() {
    // The calendar-queue rework: trace frames chain one event per
    // device cell instead of pre-pushing every (row, device) pair, and
    // superseded epoch-guarded events are compacted away. Even in a
    // churny, crashing, lossy run the queue must stay below the old
    // constructor pre-push floor of frames × devices events.
    let frames = 24;
    let s = ScenarioBuilder::new()
        .scheduler(SchedKind::Ras)
        .trace(TraceSpec::Weighted(4))
        .frames(frames)
        .seed(99)
        .leave_at(80.0, 1)
        .join_at(150.0, 1)
        .crash_at(40.0, 0)
        .recover_at(120.0, 0)
        .loss_rate(0.1)
        .probe_loss(0.3)
        .named("occupancy_probe")
        .build();
    let mut eng = s.engine();
    let mut peak = 0usize;
    while eng.step() {
        peak = peak.max(eng.queue_len());
    }
    assert!(eng.metrics.frames_total > 0, "the probe run produced no frames");
    let floor = frames * medge::config::SystemConfig::default().n_devices;
    assert!(peak < floor, "queue peaked at {peak} events (old pre-push floor: {floor})");
}

#[test]
fn random_fault_schedule_depends_only_on_seed() {
    let plan = FaultPlan::new().random_faults(150.0, 30.0);
    let a = plan.schedule(7, 4, 900.0);
    let b = plan.schedule(7, 4, 900.0);
    assert_eq!(a, b);
    assert_ne!(
        a,
        plan.schedule(8, 4, 900.0),
        "different seeds should produce different random fault traces"
    );
    // The expansion is part of `build()`: two identically-seeded builds
    // freeze the same concrete schedule into their extras.
    let s1 = faulted(SchedKind::Ras, 3, 55);
    let s2 = faulted(SchedKind::Ras, 3, 55);
    assert_eq!(s1.extras.faults, s2.extras.faults);
}

#[test]
fn single_faulted_scenario_replays_identically() {
    let s = faulted(SchedKind::Multi, 4, 77);
    let a = s.run();
    let b = s.run();
    assert_eq!(format!("{a:?}"), format!("{b:?}"));
}

// ---- flight-recorder determinism (PR 9) --------------------------------

/// `grid` with a flight recorder attached to every cell. The recorder
/// makes no RNG draws and never feeds back into scheduling, so the rows
/// must stay identical to the unrecorded grid up to the `trace_events`
/// tally itself.
fn recorded(grid: &Sweep) -> Sweep {
    let mut out = Sweep::new();
    for s in grid.scenarios() {
        let mut s = s.clone();
        s.extras.trace_capacity = medge::obs::DEFAULT_CAPACITY;
        out = out.add(s);
    }
    out
}

#[test]
fn recorded_chaos_grid_identical_across_thread_counts() {
    // The chaos grid fires every span source the engine has (detector,
    // retry, hedge, partition, crash, probe loss); with recorders on,
    // the rows — including the trace_events tally — must be identical
    // at any worker-thread count.
    let g = recorded(&chaos_grid());
    let seq = rows_debug(&g.clone().threads(1));
    let par4 = rows_debug(&g.clone().threads(4));
    let par2 = rows_debug(&g.threads(2));
    assert_eq!(seq.len(), 3);
    for (i, row) in seq.iter().enumerate() {
        assert_eq!(row, &par4[i], "recorded row {i} differs between --threads 1 and --threads 4");
        assert_eq!(row, &par2[i], "recorded row {i} differs between --threads 1 and --threads 2");
    }
}

#[test]
fn flight_recorder_contents_replay_identically() {
    // Stronger than the metrics wall: the surviving ring contents AND
    // the Perfetto export of every chaos cell must replay byte for byte.
    for s in recorded(&chaos_grid()).scenarios() {
        let run = || {
            let mut eng = s.engine();
            eng.drain();
            let r = eng.recorder().expect("recorder attached");
            let records: Vec<String> = r.records().map(|t| format!("{t:?}")).collect();
            (records, eng.trace_json().expect("recorder attached"))
        };
        let (recs_a, json_a) = run();
        let (recs_b, json_b) = run();
        assert!(!recs_a.is_empty(), "{}: recorder saw nothing", s.name);
        assert_eq!(recs_a, recs_b, "{}: ring contents drifted between runs", s.name);
        assert_eq!(json_a, json_b, "{}: perfetto export drifted between runs", s.name);
        assert!(json_a.contains("\"traceEvents\""), "{}: not a Chrome trace", s.name);
    }
}

#[test]
fn recording_does_not_perturb_the_simulation() {
    // The recorder is a pure observer: attaching it must not change a
    // single metric other than the trace_events tally itself.
    let plain = chaos_grid().threads(2).run();
    let rec = recorded(&chaos_grid()).threads(2).run();
    assert_eq!(plain.len(), rec.len());
    for (p, mut r) in plain.into_iter().zip(rec) {
        assert_eq!(p.trace_events, 0, "{}: unrecorded run counted events", p.label);
        assert!(r.trace_events > 0, "{}: recorded run saw nothing", r.label);
        r.trace_events = 0;
        assert_eq!(
            format!("{p:?}"),
            format!("{r:?}"),
            "recording perturbed the simulation in {}",
            p.label
        );
    }
}

#[test]
fn recorded_run_explains_placements() {
    use medge::obs::TraceEvent;
    // Every chaos cell must carry scheduler decision records, including
    // at least one explaining a successful placement (chosen device set)
    // and at least one high-priority decision.
    for s in recorded(&chaos_grid()).scenarios() {
        let mut eng = s.engine();
        eng.drain();
        let r = eng.recorder().expect("recorder attached");
        assert!(r.decisions() > 0, "{}: no decision records", s.name);
        let placed = r
            .records()
            .filter(|t| matches!(&t.event, TraceEvent::Decision(d) if d.chosen.is_some()))
            .count();
        assert!(placed > 0, "{}: no decision explains a successful placement", s.name);
        let hp = r
            .records()
            .filter(|t| matches!(&t.event, TraceEvent::Decision(d) if d.high_priority))
            .count();
        assert!(hp > 0, "{}: no high-priority decision recorded", s.name);
    }
}
