//! Sharded-vs-flat equivalence suite (ROADMAP item 1): the fleet-cell
//! hierarchy and the lazy candidate shuffle **prune work, never change
//! answers**. Every test here pins that claim byte-for-byte:
//!
//! * any `cell_size` (auto, degenerate 1-device cells, odd spans, one
//!   giant cell) must produce identical metric rows and identical
//!   `json_rows` output — the cell layer only moves devices between the
//!   per-cell uniform fast path and the exact per-device path;
//! * RAS's lazy cell descent (forced via `lazy_shuffle_cutover 0`) must
//!   make the same decisions, charge the same operation counts, and
//!   draw the same per-decision scatter stream as the eager full-fleet
//!   shuffle (forced via a huge cutover);
//! * a sharded fleet well past the auto-shard threshold must complete a
//!   conveyor run with event-queue occupancy far below the old
//!   O(rows × devices) constructor pre-push floor.

use medge::metrics::report::json_rows;
use medge::scenario::{Scenario, ScenarioBuilder, SchedKind, Sweep};
use medge::workload::trace::TraceSpec;

/// A churn-heavy conveyor scenario: leaves, rejoins, a crash, and a
/// lossy link drive every cell bookkeeping path (note_busy/note_idle,
/// set_active, eviction re-keys, reconstruct-after-rebuild).
fn churny(kind: SchedKind, load: u8, cell: usize, cutover: Option<usize>) -> Scenario {
    let mut b = ScenarioBuilder::new()
        .scheduler(kind)
        .trace(TraceSpec::Weighted(load))
        .frames(12)
        .seed(1234)
        .cell_size(cell)
        .leave_at(80.0, 1)
        .join_at(150.0, 1)
        .crash_at(40.0, 0)
        .recover_at(120.0, 0)
        .loss_rate(0.1)
        .named(format!("{}_{}", kind.label(), load));
    if let Some(c) = cutover {
        b = b.lazy_shuffle_cutover(c);
    }
    b.build()
}

fn rows_json(scenarios: Vec<Scenario>) -> String {
    let mut sweep = Sweep::new().threads(2);
    for s in scenarios {
        sweep = sweep.add(s);
    }
    json_rows(&sweep.run())
}

#[test]
fn cell_size_never_changes_decisions() {
    // The full scheduler zoo under churn, across cell layouts from
    // degenerate (span 1: every device its own cell) to one giant cell
    // (span ≥ fleet: the flat layout). Byte-identical JSON or the cell
    // layer leaked into a decision.
    let grid = |cell: usize| {
        rows_json(
            [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi]
                .into_iter()
                .flat_map(|k| [churny(k, 2, cell, None), churny(k, 4, cell, None)])
                .collect(),
        )
    };
    let auto = grid(0);
    for cell in [1, 3, 7, 64] {
        assert_eq!(auto, grid(cell), "cell_size {cell} changed a decision");
    }
}

#[test]
fn energy_and_cloud_rows_are_cell_size_invariant() {
    // The energy-aware scheduler takes the exact per-member path in
    // every cell (its score depends on per-device battery levels), and
    // the cloud pseudo-device must stay outside the cell bookkeeping.
    let run = |cell: usize| {
        let s = ScenarioBuilder::new()
            .scheduler(SchedKind::Energy)
            .trace(TraceSpec::Weighted(4))
            .frames(12)
            .seed(77)
            .cell_size(cell)
            .energy(medge::energy::EnergyModel::pi2b())
            .battery_j(300.0)
            .cloud(20e6, 40.0)
            .loss_rate(0.05)
            .named("energy_cloud")
            .build();
        format!("{:?}", s.run())
    };
    let auto = run(0);
    for cell in [1, 2, 5] {
        assert_eq!(auto, run(cell), "cell_size {cell} changed an energy/cloud decision");
    }
}

#[test]
fn lazy_descent_is_decision_identical_to_the_eager_scan() {
    // RAS two-regime equivalence: a huge cutover pins the eager
    // full-fleet shuffle, cutover 0 forces the lazy cell descent on
    // every decision. Both regimes consume the same per-decision
    // scatter stream, so decisions, ops, and RNG draws must all agree —
    // the rows match byte for byte, whatever the cell layout.
    let grid = |cutover: usize, cell: usize| {
        rows_json(
            [SchedKind::Ras, SchedKind::Multi]
                .into_iter()
                .flat_map(|k| {
                    [churny(k, 2, cell, Some(cutover)), churny(k, 4, cell, Some(cutover))]
                })
                .collect(),
        )
    };
    let eager = grid(usize::MAX, 0);
    for cell in [0, 1, 3] {
        assert_eq!(
            eager,
            grid(0, cell),
            "lazy descent (cell_size {cell}) diverged from the eager scan"
        );
    }
}

#[test]
fn json_rows_replay_byte_identically() {
    // The export itself is part of the equivalence contract: two runs of
    // the same grid must serialize to the same bytes.
    let grid = || rows_json(vec![churny(SchedKind::Ras, 3, 0, None)]);
    assert_eq!(grid(), grid());
}

#[test]
fn sharded_fleet_completes_with_bounded_queue_occupancy() {
    // 600 devices is past the auto-shard threshold (512): the fleet
    // shards into ~√n-device cells, RAS's default cutover (256) forces
    // the lazy descent on every decision, and the conveyor chains one
    // TraceFrame per cell. The old constructor pre-pushed every frame:
    // 24 rows × 600 devices = 14 400 events before the run even started.
    // Occupancy must now track live work only.
    let frames = 24;
    let devices = 600;
    let s = ScenarioBuilder::new()
        .scheduler(SchedKind::Ras)
        .trace(TraceSpec::Weighted(2))
        .devices(devices)
        .frames(frames)
        .seed(42)
        .named("scale_600")
        .build();
    let mut eng = s.engine();
    let mut peak = 0usize;
    while eng.step() {
        peak = peak.max(eng.queue_len());
    }
    assert!(eng.metrics.frames_total > 0, "the scaled conveyor produced no frames");
    assert!(eng.metrics.hp_completed > 0, "no task ever completed at scale");
    let floor = frames * devices;
    assert!(
        peak < floor / 2,
        "queue peaked at {peak} events — O(rows × devices) occupancy is back (floor {floor})"
    );
}

#[test]
fn scaled_fleet_is_still_cell_size_invariant() {
    // The same 600-device run under three layouts: auto (~25-device
    // cells), a skewed explicit span, and one giant cell (flat layout).
    // All three run the lazy descent (600 actives > default cutover),
    // so this is sharded-vs-flat at scale, not just at toy sizes.
    let run = |cell: usize| {
        let s = ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(2))
            .devices(600)
            .frames(6)
            .seed(42)
            .cell_size(cell)
            .named("scale_600")
            .build();
        format!("{:?}", s.run())
    };
    let auto = run(0);
    for cell in [37, 600] {
        assert_eq!(auto, run(cell), "cell_size {cell} changed a decision at scale");
    }
}
