//! Generative-workload integration suite: arrival-process statistics at
//! the compiled-plan level, the conveyor-as-generator equivalence the
//! golden snapshots depend on, the IdBatch spill path end to end, and
//! offered-load/admission accounting identities.

use medge::config::SystemConfig;
use medge::metrics::report;
use medge::scenario::{ScenarioBuilder, SchedKind};
use medge::time::secs;
use medge::workload::gen::{
    empirical_rate_per_min, index_of_dispersion, ArrivalProcess, Catalog, GenSpec, TaskClass,
    Workload,
};
use medge::workload::trace::TraceSpec;

/// The golden-trace scenario shape (rust/tests/golden_trace.rs), built
/// through the given workload entry point.
fn golden_shape(kind: SchedKind, via_workload: bool) -> medge::metrics::Metrics {
    let mut b = ScenarioBuilder::new()
        .scheduler(kind)
        .frames(16)
        .seed(2024)
        .device_speed(1, 1.25)
        .leave_at(90.0, 2)
        .join_at(200.0, 2)
        .congestion_at(120.0, 36e6, 0.5)
        .crash_at(60.0, 3)
        .recover_at(150.0, 3)
        .loss_rate(0.05)
        .probe_loss(0.25)
        .named(format!("G_{}", kind.label()));
    b = if via_workload {
        b.workload(Workload::conveyor(TraceSpec::Weighted(3)))
    } else {
        b.trace(TraceSpec::Weighted(3))
    };
    b.build().run()
}

/// Acceptance criterion: the conveyor trace re-expressed as a workload
/// reproduces the golden-trace rows byte for byte — for every scheduler,
/// through the full fault/churn/congestion path the snapshots pin.
#[test]
fn conveyor_as_workload_reproduces_golden_rows_byte_for_byte() {
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi] {
        let via_trace = report::json_rows(&[golden_shape(kind, false)]);
        let via_workload = report::json_rows(&[golden_shape(kind, true)]);
        assert_eq!(
            via_trace,
            via_workload,
            "{}: Workload::Conveyor must replay the trace path byte-identically",
            kind.label()
        );
    }
}

#[test]
fn compiled_poisson_plan_matches_its_rate_spec() {
    let cfg = SystemConfig { seed: 5, ..Default::default() };
    let spec = GenSpec {
        arrivals: ArrivalProcess::Poisson { rate_per_min: 24.0 },
        catalog: Catalog::edge_serving(&cfg),
        admission_cap: 0,
    };
    let horizon = secs(3.0 * 3600.0);
    let plan = spec.compile(&cfg, horizon).unwrap();
    let times: Vec<u64> = plan.arrivals.iter().map(|a| a.at).collect();
    let rate = empirical_rate_per_min(&times, horizon);
    assert!((rate - 24.0).abs() < 2.0, "empirical plan rate {rate} vs spec 24/min");
    let d = index_of_dispersion(&times, horizon, secs(60.0));
    assert!((0.6..1.6).contains(&d), "poisson plan dispersion {d} should be ≈1");
}

#[test]
fn compiled_mmpp_plan_is_bursty() {
    let cfg = SystemConfig { seed: 9, ..Default::default() };
    let spec = GenSpec {
        arrivals: ArrivalProcess::Mmpp {
            on_rate_per_min: 60.0,
            off_rate_per_min: 1.0,
            mean_on_s: 30.0,
            mean_off_s: 120.0,
        },
        catalog: Catalog::edge_serving(&cfg),
        admission_cap: 0,
    };
    let horizon = secs(3.0 * 3600.0);
    let plan = spec.compile(&cfg, horizon).unwrap();
    let times: Vec<u64> = plan.arrivals.iter().map(|a| a.at).collect();
    let d = index_of_dispersion(&times, horizon, secs(60.0));
    assert!(d > 2.0, "MMPP plan must be overdispersed vs Poisson, got {d}");
    // Duty-weighted mean: (60·30 + 1·120) / 150 = 12.8/min.
    let rate = empirical_rate_per_min(&times, horizon);
    assert!((rate - 12.8).abs() < 4.0, "MMPP mean rate {rate} vs expectation 12.8");
}

/// A class whose batch size exceeds the old IdBatch cap of 4: the whole
/// arrival → dispatch → placement/rejection pipeline must flow through
/// the spill path without truncation or panic, atomically per batch.
#[test]
fn oversized_batches_flow_through_the_engine() {
    let cfg = SystemConfig { seed: 31, ..Default::default() };
    let image_mbits = cfg.image_bytes as f64 * 8.0 / 1e6;
    let catalog = Catalog::new(vec![TaskClass::low(
        "wide",
        2.5 * cfg.frame_period_s,
        image_mbits,
        cfg.lp2_proc_s,
        cfg.lp4_proc_s,
    )
    .batch(7)]);
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi] {
        let m = ScenarioBuilder::new()
            .scheduler(kind)
            .workload(Workload::generative(
                ArrivalProcess::Poisson { rate_per_min: 3.0 },
                catalog.clone(),
            ))
            .minutes(10.0)
            .seed(31)
            .build()
            .run();
        assert!(m.gen_arrivals > 0, "{}: no arrivals", m.label);
        // Every offered task is a multiple of the batch width, and the
        // batch is atomic: placements come in multiples of 7 too.
        assert_eq!(m.offered_tasks % 7, 0, "{}: offered {}", m.label, m.offered_tasks);
        assert_eq!(m.offered_tasks, m.lp_generated + m.admission_dropped + m.offline_dropped);
        assert_eq!(
            m.lp_generated,
            m.lp_allocated_initial + m.lp_alloc_failures,
            "{}: batch atomicity lost",
            m.label
        );
        assert_eq!(m.lp_allocated_initial % 7, 0, "{}: partial batch placed", m.label);
        assert_eq!(
            m.two_core_allocs + m.four_core_allocs,
            m.lp_allocated_initial + m.lp_realloc_success,
            "{}: core-mix identity",
            m.label
        );
    }
}

/// Generative accounting identities: offered = generated + dropped,
/// every completion carries an end-to-end latency sample, and the
/// percentile chain is monotone.
#[test]
fn offered_load_and_latency_accounting_close() {
    let cfg = SystemConfig { seed: 47, ..Default::default() };
    let m = ScenarioBuilder::new()
        .scheduler(SchedKind::Ras)
        .workload(Workload::Generative(GenSpec {
            arrivals: ArrivalProcess::Mmpp {
                on_rate_per_min: 30.0,
                off_rate_per_min: 2.0,
                mean_on_s: 40.0,
                mean_off_s: 80.0,
            },
            catalog: Catalog::edge_serving(&cfg),
            admission_cap: 24,
        }))
        .minutes(15.0)
        .seed(47)
        .build()
        .run();
    assert!(m.offered_tasks > 0);
    assert_eq!(
        m.offered_tasks,
        m.hp_generated + m.lp_generated + m.admission_dropped + m.offline_dropped
    );
    assert_eq!(
        m.lat_lp_e2e.count,
        m.lp_completed_initial + m.lp_completed_realloc,
        "every LP completion records one e2e sample"
    );
    assert!(m.lat_lp_e2e.p50_ms() <= m.lat_lp_e2e.p95_ms());
    assert!(m.lat_lp_e2e.p95_ms() <= m.lat_lp_e2e.p99_ms());
    assert!(m.lat_lp_e2e.p99_ms() <= m.lat_lp_e2e.max_ms() + 1e-9);
    if m.lat_lp_e2e.count > 0 {
        // Completions beat their (class) deadline by construction: the
        // loosest class bound caps the e2e tail.
        assert!(m.lat_lp_e2e.max_ms() <= 3.0 * cfg.frame_period_s * 1000.0 + 1.0);
    }
}

/// A closed-loop population bounds its own offered load: doubling the
/// user count roughly doubles arrivals, and the stream stays within the
/// population's cycle-time budget.
#[test]
fn closed_loop_population_shapes_offered_load() {
    let cfg = SystemConfig { seed: 53, ..Default::default() };
    let run = |users: u32| {
        ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .workload(Workload::generative(
                ArrivalProcess::ClosedLoop { users, think_s: 25.0 },
                Catalog::edge_serving(&cfg),
            ))
            .minutes(20.0)
            .seed(53)
            .build()
            .run()
    };
    let small = run(3);
    let big = run(6);
    assert!(small.gen_arrivals > 0);
    let ratio = big.gen_arrivals as f64 / small.gen_arrivals as f64;
    assert!(
        (1.4..2.6).contains(&ratio),
        "doubling the population should ≈double arrivals: {} vs {} (ratio {ratio:.2})",
        small.gen_arrivals,
        big.gen_arrivals
    );
}
