//! Integration: the rust PJRT runtime loads the AOT artifacts produced by
//! `make artifacts` and runs real inference — the full L1→L2→L3 bridge.
//! Skipped (with a message) when artifacts are absent or the binary was
//! built without the `pjrt` feature (the default offline configuration).

use medge::runtime::{default_artifacts_dir, image::synth_frame, InferenceEngine, Stage, IMAGE_ELEMS};

fn engine() -> Option<InferenceEngine> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    let dir = default_artifacts_dir();
    if !dir.join("detector.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(InferenceEngine::load(&dir).expect("artifacts should compile on the CPU PJRT client"))
}

#[test]
fn loads_and_reports_platform() {
    let Some(e) = engine() else { return };
    assert!(e.platform().to_lowercase().contains("cpu") || !e.platform().is_empty());
}

#[test]
fn all_stages_produce_logits() {
    let Some(e) = engine() else { return };
    let img = synth_frame(1, true);
    for (stage, n) in [(Stage::Detector, 2), (Stage::Binary, 2), (Stage::Classifier, 4)] {
        let logits = e.infer(stage, &img).unwrap();
        assert_eq!(logits.0.len(), n, "{stage:?}");
        assert!(logits.0.iter().all(|v| v.is_finite()), "{stage:?}: {:?}", logits.0);
    }
}

#[test]
fn inference_is_deterministic() {
    let Some(e) = engine() else { return };
    let img = synth_frame(7, true);
    let a = e.infer(Stage::Classifier, &img).unwrap();
    let b = e.infer(Stage::Classifier, &img).unwrap();
    assert_eq!(a.0, b.0);
}

#[test]
fn different_frames_give_different_logits() {
    let Some(e) = engine() else { return };
    let a = e.infer(Stage::Classifier, &synth_frame(1, true)).unwrap();
    let b = e.infer(Stage::Classifier, &synth_frame(2, false)).unwrap();
    assert_ne!(a.0, b.0);
}

#[test]
fn pipeline_runs_end_to_end() {
    let Some(e) = engine() else { return };
    let r = e.pipeline(&synth_frame(3, true)).unwrap();
    // Whatever the (untrained) detector decides, the result must be
    // structurally consistent with the staged pipeline.
    if !r.object_present {
        assert!(r.recyclable.is_none() && r.class.is_none());
    } else if r.recyclable == Some(false) {
        assert!(r.class.is_none());
    } else if r.recyclable == Some(true) {
        assert!(r.class.unwrap() < 4);
    }
}

#[test]
fn rejects_wrong_input_size() {
    let Some(e) = engine() else { return };
    assert!(e.infer(Stage::Detector, &vec![0.0; IMAGE_ELEMS - 1]).is_err());
}
