//! Anytime-truncation property suite: randomized stage plans × arrival
//! processes × schedulers, asserting the identities that make mid-flight
//! truncation trustworthy —
//!
//! * the accuracy ledger closes through cuts (`Σ rung_completions ==
//!   deadline-met`, mean delivered accuracy bounded below by the worst
//!   mandatory-prefix credit and above by the best rung),
//! * a cut never lands below the mandatory prefix (`stages_skipped` is
//!   bounded by the optional-stage budget of the plans in play),
//! * `truncated_completions ≤ pressure_cuts` (every truncation was
//!   armed by a survey) and every full-depth twin truncates nothing,
//!
//! plus the acceptance scenario from the issue: under MMPP overload the
//! pressure controller strictly raises deadlines met with accuracy
//! goodput no worse — for every scheduler, including GREEDY — and the
//! battery regression: a draining device survives on truncated work it
//! could not survive at full depth (`pressure(_, 0)` is the rescue-only
//! mode: no backlog escalation, cuts fire only for deadline- or
//! battery-doomed tasks).

use medge::config::SystemConfig;
use medge::energy::EnergyModel;
use medge::experiments::{anytime_catalog, frontier_arrivals, ANYTIME_BACKLOG, ANYTIME_CHECK_S};
use medge::metrics::Metrics;
use medge::scenario::{ScenarioBuilder, SchedKind};
use medge::util::prop::forall;
use medge::util::Rng;
use medge::workload::gen::{
    ArrivalProcess, Catalog, Ladder, ModelVariant, TaskClass, Workload,
};
use medge::workload::trace::TraceSpec;

/// A random valid ladder (1–3 rungs descending on every axis from the
/// paper's stage-3 cost point) with anytime stage plans attached to a
/// random subset of rungs: 2–4 stages, a mandatory prefix strictly
/// shorter than the plan (every staged rung stays cuttable), time
/// fractions and accuracy credits drawn positive and closed exactly
/// (last entry = remainder) so `Ladder::validate` accepts every draw.
fn random_staged_ladder(rng: &mut Rng, cfg: &SystemConfig) -> Ladder {
    let depth = 1 + rng.index(3);
    let mut acc = 0.90 + rng.gen_f64() * 0.09;
    let mut p2 = cfg.lp2_proc_s;
    let mut p4 = cfg.lp4_proc_s;
    let mut mbits = cfg.image_bytes as f64 * 8.0 / 1e6;
    let mut rungs = Vec::with_capacity(depth);
    for i in 0..depth {
        let mut v = ModelVariant::new(&format!("r{i}"), acc, mbits, p2, p4);
        // ~2/3 of rungs carry a stage plan; the rest stay monolithic so
        // every run mixes cuttable and uncuttable work.
        if rng.index(3) < 2 {
            let n = 2 + rng.index(3); // 2..=4 stages
            let w: Vec<f64> = (0..n).map(|_| 0.2 + rng.gen_f64()).collect();
            let (tw, mut stages) = (w.iter().sum::<f64>(), Vec::with_capacity(n));
            let (mut frac_left, mut credit_left) = (1.0, acc);
            for (j, &wj) in w.iter().enumerate() {
                let (f, c) = if j + 1 == n {
                    (frac_left, credit_left) // exact closure, no drift
                } else {
                    (wj / tw, acc * wj / tw)
                };
                frac_left -= f;
                credit_left -= c;
                stages.push((f, c));
            }
            v = v.staged(1 + rng.index(n - 1), &stages);
        }
        rungs.push(v);
        let shrink = 0.35 + rng.gen_f64() * 0.45;
        acc *= 0.75 + rng.gen_f64() * 0.20;
        p2 *= shrink;
        p4 *= shrink;
        mbits *= shrink;
    }
    let ladder = Ladder::new(rungs);
    ladder.validate().expect("random staged ladder construction must stay valid");
    ladder
}

fn random_process(rng: &mut Rng) -> ArrivalProcess {
    match rng.index(3) {
        0 => ArrivalProcess::Poisson { rate_per_min: 8.0 + rng.gen_f64() * 20.0 },
        1 => ArrivalProcess::Mmpp {
            on_rate_per_min: 20.0 + rng.gen_f64() * 30.0,
            off_rate_per_min: 1.0,
            mean_on_s: 30.0 + rng.gen_f64() * 40.0,
            mean_off_s: 30.0 + rng.gen_f64() * 60.0,
        },
        _ => ArrivalProcess::Diurnal {
            base_rate_per_min: 8.0 + rng.gen_f64() * 10.0,
            amplitude: rng.gen_f64(),
            period_s: 120.0 + rng.gen_f64() * 240.0,
        },
    }
}

/// The worst accuracy any deadline-met completion can credit: for a
/// staged rung the mandatory-prefix credit (the deepest legal cut), for
/// a monolithic rung its full accuracy.
fn min_delivered_credit(ladder: &Ladder) -> f64 {
    ladder
        .rungs
        .iter()
        .map(|r| {
            if r.stages.is_empty() {
                r.accuracy
            } else {
                r.stages[..r.mandatory as usize].iter().map(|s| s.credit).sum()
            }
        })
        .fold(f64::INFINITY, f64::min)
}

/// The most stages any single truncation can skip across the ladder.
fn max_optional_stages(ladder: &Ladder) -> u64 {
    ladder.rungs.iter().map(|r| r.stages.len() as u64 - r.mandatory as u64).max().unwrap_or(0)
}

fn assert_anytime_identities(m: &Metrics, ladder: &Ladder, ctx: &str) -> Result<(), String> {
    let met = m.lp_deadline_met();
    if m.rung_completions.iter().sum::<u64>() != met {
        return Err(format!("{ctx}: Σ rung_completions != deadline-met {met}"));
    }
    if m.lp_generated != m.lp_completed_total() + m.lp_violations + m.lp_lost {
        return Err(format!("{ctx}: lp conservation broke through truncation"));
    }
    if m.truncated_completions > m.pressure_cuts {
        return Err(format!(
            "{ctx}: {} truncations landed but only {} cuts were armed",
            m.truncated_completions, m.pressure_cuts
        ));
    }
    if m.truncated_completions > met {
        return Err(format!("{ctx}: more truncated completions than deadline-met"));
    }
    if m.stages_skipped < m.truncated_completions {
        return Err(format!("{ctx}: a truncation must skip at least one stage"));
    }
    // The mandatory floor, observed through the skip ledger: no single
    // cut can skip more than the largest optional suffix in the ladder.
    if m.stages_skipped > m.truncated_completions * max_optional_stages(ladder) {
        return Err(format!(
            "{ctx}: {} stages skipped over {} truncations exceeds the optional budget {}",
            m.stages_skipped,
            m.truncated_completions,
            max_optional_stages(ladder)
        ));
    }
    if met > 0 {
        let mean = m.accuracy_per_deadline_met();
        let lo = min_delivered_credit(ladder);
        let hi = ladder.rungs.first().map(|r| r.accuracy).unwrap_or(1.0);
        if !(lo - 1e-9..=hi + 1e-9).contains(&mean) {
            return Err(format!(
                "{ctx}: mean delivered accuracy {mean} outside credit bounds [{lo}, {hi}]"
            ));
        }
    }
    Ok(())
}

#[test]
fn anytime_identities_hold_across_random_plans_and_processes() {
    forall("anytime identities (random staged ladder × process × scheduler)", 8, |rng| {
        let cfg = SystemConfig::default();
        let ladder = random_staged_ladder(rng, &cfg);
        let process = random_process(rng);
        let kind =
            [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi, SchedKind::Greedy][rng.index(4)];
        let seed = rng.next_u64();
        let catalog = Catalog::new(vec![TaskClass::low(
            "stage3",
            cfg.frame_period_s * (0.8 + rng.gen_f64() * 0.8),
            0.0,
            1.0,
            0.8,
        )
        .batch(1 + rng.index(2) as u32)
        .ladder(ladder.clone())]);
        let base = ScenarioBuilder::new()
            .scheduler(kind)
            .workload(Workload::generative(process, catalog))
            .minutes(5.0)
            .seed(seed);
        let check_s = 0.25 + rng.gen_f64() * 0.75;
        let backlog = [0u32, 4, 8][rng.index(3)]; // 0 = rescue-only mode
        let cut = base.clone().pressure(check_s, backlog).build().run();
        let full = base.build().run();
        if cut.gen_arrivals == 0 {
            return Err("plan fired no arrivals".to_string());
        }
        // The controller never perturbs the offered load.
        if cut.offered_tasks != full.offered_tasks {
            return Err(format!(
                "{}: pressure twin offered {} tasks, full twin {}",
                cut.label, cut.offered_tasks, full.offered_tasks
            ));
        }
        if full.truncated_completions != 0 || full.pressure_events != 0 || full.pressure_cuts != 0
        {
            return Err(format!("{}: the controller-off twin truncated", full.label));
        }
        assert_anytime_identities(&cut, &ladder, &cut.label)?;
        assert_anytime_identities(&full, &ladder, &full.label)
    });
}

/// One anytime cell: the staged stage-3 family under MMPP pressure at
/// `rate` arrivals/min (ON state), controller on or off.
fn anytime_run(kind: SchedKind, cut: bool, rate: f64, seed: u64, minutes: f64) -> Metrics {
    let cfg = SystemConfig::default();
    let mut b = ScenarioBuilder::new()
        .scheduler(kind)
        .workload(Workload::generative(frontier_arrivals(rate), anytime_catalog(&cfg)))
        .minutes(minutes)
        .seed(seed)
        .named(format!("{}_{}", kind.label(), if cut { "cut" } else { "full" }));
    if cut {
        b = b.pressure(ANYTIME_CHECK_S, ANYTIME_BACKLOG);
    }
    b.build().run()
}

/// THE acceptance criterion: under MMPP overload, turning the pressure
/// controller on strictly raises deadlines met — over the *same*
/// offered load — while total delivered accuracy per offered task
/// (goodput) does not fall, for every scheduler. Mean accuracy per
/// completion may only move down or hold: truncation trades tail
/// accuracy for completions, never the reverse.
#[test]
fn overload_truncation_strictly_raises_deadlines_met_on_every_scheduler() {
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi, SchedKind::Greedy] {
        let full = anytime_run(kind, false, 40.0, 2025, 12.0);
        let cut = anytime_run(kind, true, 40.0, 2025, 12.0);
        assert_eq!(
            full.offered_tasks,
            cut.offered_tasks,
            "{}: twins must face the same arrivals",
            kind.label()
        );
        assert!(
            full.lp_deadline_met() > 0,
            "{}: the controller-off twin should still complete work in OFF windows",
            kind.label()
        );
        assert!(
            cut.truncated_completions > 0,
            "{}: overload must force truncated completions",
            kind.label()
        );
        assert!(
            cut.lp_deadline_met() > full.lp_deadline_met(),
            "{}: truncation must strictly raise deadlines met ({} vs {})",
            kind.label(),
            cut.lp_deadline_met(),
            full.lp_deadline_met()
        );
        assert!(
            cut.delivered_accuracy_rate() >= full.delivered_accuracy_rate(),
            "{}: accuracy goodput must not fall ({:.4} vs {:.4})",
            kind.label(),
            cut.delivered_accuracy_rate(),
            full.delivered_accuracy_rate()
        );
        assert!(
            cut.accuracy_per_deadline_met() <= full.accuracy_per_deadline_met() + 1e-9,
            "{}: mean accuracy per completion can only drop under truncation",
            kind.label()
        );
    }
}

/// The battery regression pinned by this PR's bugfix: truncating a task
/// on a battery device re-runs the depletion prediction with the
/// shortened plan (`energy_task_end` → `arm_battery`), so a
/// near-drained device survives work it could not survive at full
/// depth. `pressure(_, 0)` keeps backlog escalation off — every cut
/// here came from the rescue clause (deadline- or battery-doomed), the
/// exact path the bug sat on.
#[test]
fn battery_doomed_rescue_truncates_and_outlives_the_full_depth_twin() {
    let cfg = SystemConfig::default();
    let base = || {
        ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(3))
            .frames(12)
            .seed(53)
            .lp_ladder(Ladder::stage3_family_staged(&cfg))
            .energy(EnergyModel::pi2b())
            .battery_j(150.0)
    };
    let full = base().build().run();
    let rescued = base().pressure(0.25, 0).build().run();
    assert!(
        full.battery_depletions >= 1,
        "calibration: a 150 J battery must not survive 12 frames at full depth"
    );
    assert!(
        rescued.pressure_cuts >= 1 && rescued.truncated_completions >= 1,
        "the rescue clause must arm and land cuts ({} armed, {} landed)",
        rescued.pressure_cuts,
        rescued.truncated_completions
    );
    assert!(
        rescued.battery_depletions <= full.battery_depletions,
        "truncated work must not drain more batteries than full-depth work ({} vs {})",
        rescued.battery_depletions,
        full.battery_depletions
    );
    assert!(
        rescued.lp_deadline_met() >= full.lp_deadline_met(),
        "surviving devices must bank at least as many deadlines ({} vs {})",
        rescued.lp_deadline_met(),
        full.lp_deadline_met()
    );
    for m in [&full, &rescued] {
        assert_eq!(
            m.lp_generated,
            m.lp_completed_total() + m.lp_violations + m.lp_lost,
            "{}: lp conservation through depletion + truncation",
            m.label
        );
    }
}
