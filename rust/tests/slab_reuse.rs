//! Slab-reuse regression suite: the PR 2 stale-event guarantees — a
//! re-placed preemption/churn/crash victim must never be completed,
//! transferred, or finished by events queued against its dead placement —
//! now rest on the engine slab's generation word instead of an explicit
//! counter. These tests hammer the recycle paths (preemption storms,
//! crashes with re-offers, churn) and check the accounting identities
//! that a stale finish/transfer leaking through would break.

use medge::scenario::{Scenario, ScenarioBuilder, SchedKind};
use medge::workload::trace::TraceSpec;

/// Heavy recycle mix: overload (preemption traffic), a crash with
/// re-offers, graceful churn, loss. Every slab slot is recycled many
/// times over this run.
fn stormy(kind: SchedKind, seed: u64) -> Scenario {
    ScenarioBuilder::new()
        .scheduler(kind)
        .trace(TraceSpec::Weighted(4))
        .frames(18)
        .seed(seed)
        .crash_at(50.0, 1)
        .recover_at(140.0, 1)
        .leave_at(90.0, 2)
        .join_at(200.0, 2)
        .loss_rate(0.1)
        .probe_loss(0.2)
        .named(format!("{}_storm_{}", kind.label(), seed))
        .build()
}

#[test]
fn recycle_storm_keeps_completion_identities() {
    for kind in [SchedKind::Ras, SchedKind::Wps, SchedKind::Multi] {
        for seed in [3u64, 17, 1009] {
            let m = stormy(kind, seed).run();
            // A stale HpFinish/LpFinish acting on a re-placed task would
            // double-count a completion and break these inequalities.
            assert!(
                m.hp_completed + m.hp_violations
                    <= m.hp_allocated_no_preempt + m.hp_allocated_with_preempt,
                "{}: HP completions exceed placements",
                m.label
            );
            assert!(
                m.lp_completed_initial + m.lp_completed_realloc + m.lp_violations
                    <= m.lp_allocated_initial + m.lp_realloc_success,
                "{}: LP completions exceed placements",
                m.label
            );
            // A stale TransferStart would start a medium flow for a dead
            // placement and complete offloads that were never placed.
            assert!(m.offloaded_completed <= m.offloaded_total, "{}", m.label);
            // Global identities survive the storm.
            assert_eq!(
                m.hp_generated,
                m.hp_allocated_no_preempt + m.hp_allocated_with_preempt + m.hp_rejected,
                "{}: hp accounting",
                m.label
            );
            assert_eq!(
                m.two_core_allocs + m.four_core_allocs,
                m.lp_allocated_initial + m.lp_realloc_success,
                "{}: core-mix accounting",
                m.label
            );
            // Crash re-offer accounting closes once the queue drains.
            assert_eq!(
                m.crash_tasks_reoffered,
                m.crash_reoffer_placed + m.crash_reoffer_dropped,
                "{}: reoffer accounting",
                m.label
            );
            assert!(m.crash_recovered_in_deadline <= m.crash_reoffer_placed, "{}", m.label);
            assert!(m.frames_completed <= m.frames_total, "{}", m.label);
        }
    }
}

#[test]
fn recycle_storm_exercises_the_recycle_paths() {
    // Guard against the suite passing vacuously: across the seeds, the
    // storm must actually preempt, crash-lose, and re-offer work.
    let mut preempted = 0u64;
    let mut lost = 0u64;
    let mut reoffered = 0u64;
    for seed in [3u64, 17, 1009] {
        let m = stormy(SchedKind::Ras, seed).run();
        preempted += m.lp_preempted;
        lost += m.crash_tasks_lost;
        reoffered += m.crash_tasks_reoffered;
    }
    assert!(preempted > 0, "storm never preempted — slab recycle path untested");
    assert!(lost > 0, "crash never lost in-flight work");
    assert!(reoffered > 0, "crash never re-offered a survivor");
}

#[test]
fn recycle_storm_is_deterministic_across_runs() {
    // Slot recycling (LIFO free list, generation bumps) is part of the
    // engine's observable state machine: replaying the same scenario must
    // reproduce byte-identical metrics.
    for kind in [SchedKind::Ras, SchedKind::Multi] {
        let s = stormy(kind, 77);
        let a = s.run();
        let b = s.run();
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "{} drifted across replays", a.label);
    }
}
