//! Golden-seed equivalence for the trait migration: the typed
//! `on_event` API must decide *identically* to the pre-redesign
//! `schedule_high` / `schedule_low` callback surface — same outcomes,
//! same ops, same internal RNG evolution — for both RAS and WPS, over
//! long random event streams. Also proves low-priority batch atomicity
//! survived the `Decision` migration.

use medge::config::SystemConfig;
use medge::coordinator::scheduler::greedy::GreedyScheduler;
use medge::coordinator::scheduler::multi::MultiScheduler;
use medge::coordinator::scheduler::ras_sched::RasScheduler;
use medge::coordinator::scheduler::wps::WpsScheduler;
use medge::coordinator::scheduler::{
    task_refs, Decision, HpOutcome, LpOutcome, Ops, Outcome, PressureCandidate, SchedEvent,
    Scheduler,
};
use medge::coordinator::task::{Task, TaskId};
use medge::time::SimTime;
use medge::util::prop::forall;
use medge::util::Rng;

/// An owned random event, replayable against either API surface.
#[derive(Debug, Clone)]
enum Ev {
    Hp(Task),
    Lp(Vec<Task>, bool),
    Complete(TaskId),
    Violation(TaskId),
    Bw(f64),
}

/// Deterministic random event stream. Complete/Violation targets are
/// drawn from previously issued ids regardless of allocation outcomes, so
/// the stream is identical for both replays by construction.
fn gen_events(rng: &mut Rng, cfg: &SystemConfig, count: usize) -> Vec<(SimTime, Ev)> {
    let mut evs = Vec::with_capacity(count);
    let mut now: SimTime = 0;
    let mut id: TaskId = 1;
    let mut issued: Vec<TaskId> = Vec::new();
    while evs.len() < count {
        now += 1 + rng.gen_range(2_000_000);
        let source = rng.index(cfg.n_devices);
        match rng.index(10) {
            0..=2 => {
                let t = Task::high(id, id, source, now, cfg);
                issued.push(id);
                id += 1;
                evs.push((now, Ev::Hp(t)));
            }
            3..=5 => {
                let n = 1 + rng.index(4) as u64;
                let deadline = now + cfg.frame_period();
                let tasks: Vec<Task> = (0..n)
                    .map(|i| Task::low(id + i, id, source, now, deadline, cfg))
                    .collect();
                for t in &tasks {
                    issued.push(t.id);
                }
                id += n;
                let realloc = rng.gen_f64() < 0.2;
                evs.push((now, Ev::Lp(tasks, realloc)));
            }
            6 | 7 => {
                if !issued.is_empty() {
                    let t = issued[rng.index(issued.len())];
                    evs.push((now, Ev::Complete(t)));
                }
            }
            8 => {
                if !issued.is_empty() {
                    let t = issued[rng.index(issued.len())];
                    evs.push((now, Ev::Violation(t)));
                }
            }
            _ => {
                let bps = cfg.link_bps * (0.4 + rng.gen_f64());
                evs.push((now, Ev::Bw(bps)));
            }
        }
    }
    evs
}

/// The pre-redesign callback surface, bound to the schedulers' inherent
/// (legacy-shaped) methods — NOT to the `on_event`-backed compat shim, so
/// the two replays exercise genuinely different dispatch paths.
trait LegacyDrive {
    fn leg_high(&mut self, now: SimTime, task: &Task) -> HpOutcome;
    fn leg_low(&mut self, now: SimTime, tasks: &[&Task], realloc: bool) -> LpOutcome;
    fn leg_complete(&mut self, now: SimTime, task: TaskId);
    fn leg_violation(&mut self, now: SimTime, task: TaskId);
    fn leg_bw(&mut self, now: SimTime, bps: f64) -> Ops;
}

impl LegacyDrive for RasScheduler {
    fn leg_high(&mut self, now: SimTime, task: &Task) -> HpOutcome {
        self.schedule_high(now, task)
    }
    fn leg_low(&mut self, now: SimTime, tasks: &[&Task], realloc: bool) -> LpOutcome {
        self.schedule_low(now, tasks, realloc)
    }
    fn leg_complete(&mut self, now: SimTime, task: TaskId) {
        self.on_complete(now, task)
    }
    fn leg_violation(&mut self, now: SimTime, task: TaskId) {
        self.on_violation(now, task)
    }
    fn leg_bw(&mut self, now: SimTime, bps: f64) -> Ops {
        self.on_bandwidth_update(now, bps)
    }
}

impl LegacyDrive for WpsScheduler {
    fn leg_high(&mut self, now: SimTime, task: &Task) -> HpOutcome {
        self.schedule_high(now, task)
    }
    fn leg_low(&mut self, now: SimTime, tasks: &[&Task], realloc: bool) -> LpOutcome {
        self.schedule_low(now, tasks, realloc)
    }
    fn leg_complete(&mut self, now: SimTime, task: TaskId) {
        self.on_complete(now, task)
    }
    fn leg_violation(&mut self, now: SimTime, task: TaskId) {
        self.on_violation(now, task)
    }
    fn leg_bw(&mut self, now: SimTime, bps: f64) -> Ops {
        self.on_bandwidth_update(now, bps)
    }
}

fn replay_legacy<S: LegacyDrive>(s: &mut S, evs: &[(SimTime, Ev)]) -> Vec<Decision> {
    evs.iter()
        .map(|(now, ev)| match ev {
            Ev::Hp(t) => Decision::from(s.leg_high(*now, t)),
            Ev::Lp(ts, r) => Decision::from(s.leg_low(*now, &task_refs(ts), *r)),
            Ev::Complete(t) => {
                s.leg_complete(*now, *t);
                Decision::ack(1)
            }
            Ev::Violation(t) => {
                s.leg_violation(*now, *t);
                Decision::ack(1)
            }
            Ev::Bw(b) => Decision::ack(s.leg_bw(*now, *b)),
        })
        .collect()
}

fn replay_typed(s: &mut dyn Scheduler, evs: &[(SimTime, Ev)]) -> Vec<Decision> {
    replay_laddered(s, evs, &[])
}

fn assert_streams_equal(legacy: &[Decision], typed: &[Decision], who: &str) {
    assert_eq!(legacy.len(), typed.len());
    for (i, (a, b)) in legacy.iter().zip(typed).enumerate() {
        assert_eq!(a, b, "{who}: decision {i} diverged between API surfaces");
    }
}

#[test]
fn ras_on_event_equals_legacy_over_1k_events() {
    let cfg = SystemConfig { seed: 42, ..Default::default() };
    let evs = gen_events(&mut Rng::seed_from_u64(0xE0E0_42), &cfg, 1000);
    // Two independent, identically-seeded instances: same internal RNG
    // stream ⇒ any divergence is the adapter's fault.
    let mut legacy = RasScheduler::new(&cfg, 0, cfg.link_bps);
    let mut typed = RasScheduler::new(&cfg, 0, cfg.link_bps);
    let a = replay_legacy(&mut legacy, &evs);
    let b = replay_typed(&mut typed, &evs);
    assert_streams_equal(&a, &b, "RAS");
    assert!(
        a.iter().any(|d| matches!(d.outcome, Outcome::LpAllocated { .. })),
        "stream should exercise allocations"
    );
    assert_eq!(legacy.state().len(), typed.state().len());
}

#[test]
fn wps_on_event_equals_legacy_over_1k_events() {
    let cfg = SystemConfig { seed: 42, ..Default::default() };
    let evs = gen_events(&mut Rng::seed_from_u64(0xE0E0_57), &cfg, 1000);
    let mut legacy = WpsScheduler::new(&cfg, 0, cfg.link_bps);
    let mut typed = WpsScheduler::new(&cfg, 0, cfg.link_bps);
    let a = replay_legacy(&mut legacy, &evs);
    let b = replay_typed(&mut typed, &evs);
    assert_streams_equal(&a, &b, "WPS");
    assert_eq!(legacy.state().len(), typed.state().len());
}

#[test]
fn equivalence_holds_across_random_seeds() {
    forall("on_event ≡ legacy (both schedulers)", 12, |rng| {
        let cfg = SystemConfig { seed: rng.next_u64(), ..Default::default() };
        let evs = gen_events(rng, &cfg, 120);
        {
            let mut legacy = RasScheduler::new(&cfg, 0, cfg.link_bps);
            let mut typed = RasScheduler::new(&cfg, 0, cfg.link_bps);
            let a = replay_legacy(&mut legacy, &evs);
            let b = replay_typed(&mut typed, &evs);
            if a != b {
                return Err("RAS decisions diverged".to_string());
            }
        }
        {
            let mut legacy = WpsScheduler::new(&cfg, 0, cfg.link_bps);
            let mut typed = WpsScheduler::new(&cfg, 0, cfg.link_bps);
            let a = replay_legacy(&mut legacy, &evs);
            let b = replay_typed(&mut typed, &evs);
            if a != b {
                return Err("WPS decisions diverged".to_string());
            }
        }
        Ok(())
    });
}

/// Replay the typed stream with every LP batch carrying `ladder` (the
/// Ev stream only generates conveyor-shaped LP tasks, so one rung spec
/// fits every batch).
fn replay_laddered(
    s: &mut dyn Scheduler,
    evs: &[(SimTime, Ev)],
    ladder: &[medge::coordinator::task::VariantRung],
) -> Vec<Decision> {
    evs.iter()
        .map(|(now, ev)| {
            let ev = match ev {
                Ev::Hp(t) => SchedEvent::HighPriority { task: t },
                Ev::Lp(ts, r) => {
                    let refs = task_refs(ts);
                    return s.on_event(
                        *now,
                        SchedEvent::LowPriorityBatch { tasks: &refs, realloc: *r, ladder },
                    );
                }
                Ev::Complete(t) => SchedEvent::Complete { task: *t },
                Ev::Violation(t) => SchedEvent::Violation { task: *t },
                Ev::Bw(b) => SchedEvent::BandwidthUpdate { bps: *b },
            };
            s.on_event(*now, ev)
        })
        .collect()
}

/// Degradation must be provably zero-cost when disabled: a one-rung
/// ladder (mirroring the conveyor class at accuracy 1.0) produces the
/// *same `Decision` stream* — outcomes, ops, variant, and internal RNG
/// evolution — as dispatching with no ladder at all, for both
/// schedulers, over a long random event stream. Combined with the
/// legacy-equivalence tests above, this chains one-rung-ladder ≡
/// no-ladder ≡ the pre-redesign callback surface.
#[test]
fn one_rung_ladder_decides_identically_to_no_ladder() {
    use medge::coordinator::task::VariantRung;
    let cfg = SystemConfig { seed: 42, ..Default::default() };
    let one_rung = [VariantRung {
        accuracy: 1.0,
        input_bytes: cfg.image_bytes,
        proc_us: [cfg.lp2_proc(), cfg.lp4_proc()],
    }];
    for (tag, seed) in [("RAS", 0xACC_01u64), ("WPS", 0xACC_02)] {
        let evs = gen_events(&mut Rng::seed_from_u64(seed), &cfg, 800);
        let (bare, laddered) = if tag == "RAS" {
            let mut a = RasScheduler::new(&cfg, 0, cfg.link_bps);
            let mut b = RasScheduler::new(&cfg, 0, cfg.link_bps);
            (replay_typed(&mut a, &evs), replay_laddered(&mut b, &evs, &one_rung))
        } else {
            let mut a = WpsScheduler::new(&cfg, 0, cfg.link_bps);
            let mut b = WpsScheduler::new(&cfg, 0, cfg.link_bps);
            (replay_typed(&mut a, &evs), replay_laddered(&mut b, &evs, &one_rung))
        };
        assert_streams_equal(&bare, &laddered, tag);
        assert!(
            bare.iter().any(|d| matches!(d.outcome, Outcome::LpAllocated { .. })),
            "{tag}: stream should exercise allocations"
        );
        assert!(
            laddered.iter().all(|d| d.variant.is_none()),
            "{tag}: a one-rung ladder must never report a variant selection"
        );
    }
}

/// A deep ladder over the same stream: decisions may legitimately
/// differ from the bare replay (that is the feature), but every variant
/// selection must be a valid rung index and only appear on allocated
/// low-priority outcomes.
#[test]
fn deep_ladder_variant_selections_are_well_formed() {
    use medge::coordinator::task::VariantRung;
    let cfg = SystemConfig { seed: 42, ..Default::default() };
    let ladder = [
        VariantRung {
            accuracy: 0.97,
            input_bytes: cfg.image_bytes,
            proc_us: [cfg.lp2_proc(), cfg.lp4_proc()],
        },
        VariantRung {
            accuracy: 0.85,
            input_bytes: cfg.image_bytes / 2,
            proc_us: [cfg.lp2_proc() / 2, cfg.lp4_proc() / 2],
        },
        VariantRung {
            accuracy: 0.70,
            input_bytes: cfg.image_bytes / 4,
            proc_us: [cfg.lp2_proc() / 4, cfg.lp4_proc() / 4],
        },
    ];
    let evs = gen_events(&mut Rng::seed_from_u64(0xACC_03), &cfg, 800);
    let mut s = RasScheduler::new(&cfg, 0, cfg.link_bps);
    let decisions = replay_laddered(&mut s, &evs, &ladder);
    for d in &decisions {
        match (&d.outcome, d.variant) {
            (Outcome::LpAllocated { .. }, Some(k)) => {
                assert!((k as usize) < ladder.len(), "variant {k} out of ladder range")
            }
            (Outcome::LpAllocated { .. }, None) => {
                panic!("laddered LP allocation must report its rung")
            }
            (_, Some(k)) => panic!("variant {k} on a non-allocated outcome: {:?}", d.outcome),
            (_, None) => {}
        }
    }
}

/// The Fresa & Champati greedy only reorders *ladder rungs*: with no
/// ladder (or a trivial one-rung ladder) there is nothing to reorder,
/// so GREEDY must produce the same `Decision` stream — outcomes, ops,
/// and internal RNG evolution — as the WPS scheduler it wraps, over a
/// long random event stream. Chained with the tests above, this pins
/// GREEDY ≡ WPS ≡ the pre-redesign callback surface whenever the
/// accuracy-density ordering has no material to work with.
#[test]
fn greedy_with_trivial_ladder_decides_identically_to_wps() {
    use medge::coordinator::task::VariantRung;
    let cfg = SystemConfig { seed: 42, ..Default::default() };
    let one_rung = [VariantRung {
        accuracy: 1.0,
        input_bytes: cfg.image_bytes,
        proc_us: [cfg.lp2_proc(), cfg.lp4_proc()],
    }];
    for (tag, ladder) in [("no-ladder", &[][..]), ("one-rung", &one_rung[..])] {
        let evs = gen_events(&mut Rng::seed_from_u64(0x47_5244), &cfg, 800);
        let mut wps = WpsScheduler::new(&cfg, 0, cfg.link_bps);
        let mut greedy = GreedyScheduler::new(&cfg, 0, cfg.link_bps);
        let a = replay_laddered(&mut wps, &evs, ladder);
        let b = replay_laddered(&mut greedy, &evs, ladder);
        assert_streams_equal(&a, &b, &format!("GREEDY/{tag}"));
        assert!(
            a.iter().any(|d| matches!(d.outcome, Outcome::LpAllocated { .. })),
            "{tag}: stream should exercise allocations"
        );
    }
}

/// Deadline-pressure rescue is a *shared* policy: every LP scheduler
/// answers the same survey with the same cuts and the same ops charge.
/// The schedulers differ in which executions exist (their placements),
/// never in how a rescue is judged — so a truncation-on/off comparison
/// between schedulers is apples-to-apples.
#[test]
fn pressure_surveys_are_judged_identically_by_every_scheduler() {
    let cfg = SystemConfig::default();
    let cand = |task, cut_finish, full_finish, battery_doomed| PressureCandidate {
        task,
        device: 0,
        cut_stage: 1,
        n_stages: 3,
        cut_finish,
        full_finish,
        deadline: 1_000,
        accuracy_loss: 0.27,
        battery_doomed,
    };
    let cands = [
        cand(1, 900, 1_500, false),  // rescue: full depth misses, cut fits
        cand(2, 700, 950, false),    // healthy: cut only under escalation
        cand(3, 800, 980, true),     // battery dies before full depth
        cand(4, 1_200, 1_800, false), // unsalvageable: even the cut misses
    ];
    for escalate in [false, true] {
        let mut scheds: Vec<Box<dyn Scheduler>> = vec![
            Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps)),
            Box::new(WpsScheduler::new(&cfg, 0, cfg.link_bps)),
            Box::new(MultiScheduler::new(&cfg, 0, cfg.link_bps, 8)),
            Box::new(GreedyScheduler::new(&cfg, 0, cfg.link_bps)),
        ];
        let mut decisions = Vec::new();
        for s in &mut scheds {
            let d = s.on_event(0, SchedEvent::Pressure { candidates: &cands, escalate });
            let Outcome::Truncate { cuts } = &d.outcome else {
                panic!("{}: pressure must answer Truncate, got {:?}", s.name(), d.outcome)
            };
            let indices: Vec<u16> = cuts.iter().map(|c| c.index).collect();
            assert!(indices.contains(&0), "{}: rescue cut missing", s.name());
            assert_eq!(indices.contains(&1), escalate, "{}: healthy task", s.name());
            assert!(indices.contains(&2), "{}: battery rescue missing", s.name());
            assert!(!indices.contains(&3), "{}: infeasible cut armed", s.name());
            for c in cuts {
                assert_eq!(
                    c.at_stage,
                    cands[c.index as usize].cut_stage,
                    "{}: cut must land on the offered boundary",
                    s.name()
                );
            }
            decisions.push(d);
        }
        for pair in decisions.windows(2) {
            assert_eq!(pair[0], pair[1], "schedulers diverged on the same survey");
        }
    }
}

/// Suspicion well-formedness (PR 8): while a device is believed down
/// ([`SchedEvent::DeviceSuspected`]), neither scheduler may place ANY
/// work on it — it leaves the candidate pool like a crashed device.
/// After [`SchedEvent::DeviceCleared`] it must become placeable again.
/// Driven over the same random event stream the equivalence suite uses,
/// so the guarantee holds under realistic interleavings, not a
/// hand-picked sequence.
#[test]
fn suspected_devices_receive_no_placements_until_cleared() {
    let cfg = SystemConfig { seed: 42, ..Default::default() };
    let suspect: usize = cfg.n_devices - 1;
    for (tag, seed) in [("RAS", 0x5059_01u64), ("WPS", 0x5059_02)] {
        let evs = gen_events(&mut Rng::seed_from_u64(seed), &cfg, 600);
        let mut s: Box<dyn Scheduler> = if tag == "RAS" {
            Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps))
        } else {
            Box::new(WpsScheduler::new(&cfg, 0, cfg.link_bps))
        };
        let (mut placed_before, mut placed_after) = (0u32, 0u32);
        for (i, (now, ev)) in evs.iter().enumerate() {
            // First third: normal. Middle third: `suspect` is believed
            // down. Last third: cleared again.
            if i == evs.len() / 3 {
                s.on_event(*now, SchedEvent::DeviceSuspected { device: suspect });
            } else if i == 2 * evs.len() / 3 {
                s.on_event(*now, SchedEvent::DeviceCleared { device: suspect });
            }
            let suspected_now = (evs.len() / 3..2 * evs.len() / 3).contains(&i);
            let d = replay_laddered(&mut *s, std::slice::from_ref(&(*now, ev.clone())), &[]);
            for dec in &d {
                if let Outcome::LpAllocated { allocs } = &dec.outcome {
                    for a in allocs {
                        if a.device == suspect {
                            assert!(
                                !suspected_now,
                                "{tag}: event {i} placed task {} on suspected device {suspect}",
                                a.task
                            );
                            if i < evs.len() / 3 {
                                placed_before += 1;
                            } else {
                                placed_after += 1;
                            }
                        }
                    }
                }
            }
        }
        // Guard against vacuity: the device must actually attract work
        // when it is believed up, on both sides of the window.
        assert!(placed_before > 0, "{tag}: device {suspect} never placed before suspicion");
        assert!(placed_after > 0, "{tag}: device {suspect} never placed after clearing");
    }
}

/// The paper treats a low-priority batch atomically: a rejection must
/// leave the committed state exactly as it was (partial placements rolled
/// back), and that guarantee must survive the `Decision` migration on
/// both schedulers.
#[test]
fn lp_batch_atomicity_survives_decision_migration() {
    let cfg = SystemConfig::default();
    let mut scheds: Vec<Box<dyn Scheduler>> = vec![
        Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps)),
        Box::new(WpsScheduler::new(&cfg, 0, cfg.link_bps)),
    ];
    for sched in &mut scheds {
        let now = 0;
        let deadline = now + cfg.frame_period();
        let mut id: TaskId = 1;
        let mut saw_rejection = false;
        // Keep throwing 4-task batches at the same window until capacity
        // runs out; the rejecting call must not leak partial placements.
        for _ in 0..10 {
            let batch: Vec<Task> =
                (0..4).map(|i| Task::low(id + i, id, 0, now, deadline, &cfg)).collect();
            id += 4;
            let live_before = sched.state().len();
            let d = sched.on_event(
                now,
                SchedEvent::LowPriorityBatch { tasks: &task_refs(&batch), realloc: false, ladder: &[] },
            );
            match d.outcome {
                Outcome::LpAllocated { allocs } => {
                    assert_eq!(allocs.len(), 4, "{}: batch is all-or-nothing", sched.name());
                    assert_eq!(sched.state().len(), live_before + 4, "{}", sched.name());
                }
                Outcome::LpRejected => {
                    saw_rejection = true;
                    assert_eq!(
                        sched.state().len(),
                        live_before,
                        "{}: rejected batch leaked partial placements",
                        sched.name()
                    );
                    break;
                }
                other => panic!("{}: unexpected outcome {other:?}", sched.name()),
            }
        }
        assert!(saw_rejection, "{}: capacity never ran out in 10 batches", sched.name());
    }
}
