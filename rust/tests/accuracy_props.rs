//! Accuracy-accounting property suite: randomized model-variant ladders
//! × arrival processes × schedulers, asserting the identities that make
//! delivered-accuracy numbers trustworthy —
//!
//! * `lp deadline-met == Σ per-rung completions` (nothing double- or
//!   un-counted),
//! * `min rung accuracy ≤ mean delivered accuracy ≤ max rung accuracy`,
//! * `offered == hp + lp + admission_dropped + offline_dropped` still
//!   closes through degradation,
//! * depth-1 ladders never degrade,
//!
//! plus the acceptance scenario from the issue: under MMPP overload a
//! 3-rung ladder strictly raises deadlines met and strictly lowers the
//! mean delivered accuracy vs its no-degradation twin, and adding rungs
//! never *systematically* reduces deadlines met for the same seed.

use medge::config::SystemConfig;
use medge::experiments::{frontier_arrivals, frontier_catalog};
use medge::metrics::Metrics;
use medge::scenario::{ScenarioBuilder, SchedKind};
use medge::util::prop::forall;
use medge::util::Rng;
use medge::workload::gen::{ArrivalProcess, Catalog, Ladder, ModelVariant, TaskClass, Workload};

/// A random valid ladder: 1–3 rungs descending on every axis from the
/// paper's stage-3 cost point.
fn random_ladder(rng: &mut Rng, cfg: &SystemConfig) -> Ladder {
    let depth = 1 + rng.index(3);
    let mut acc = 0.90 + rng.gen_f64() * 0.09;
    let mut p2 = cfg.lp2_proc_s;
    let mut p4 = cfg.lp4_proc_s;
    let mut mbits = cfg.image_bytes as f64 * 8.0 / 1e6;
    let mut rungs = Vec::with_capacity(depth);
    for i in 0..depth {
        rungs.push(ModelVariant::new(&format!("r{i}"), acc, mbits, p2, p4));
        let shrink = 0.35 + rng.gen_f64() * 0.45;
        acc *= 0.75 + rng.gen_f64() * 0.20;
        p2 *= shrink;
        p4 *= shrink;
        mbits *= shrink;
    }
    let ladder = Ladder::new(rungs);
    ladder.validate().expect("random ladder construction must stay valid");
    ladder
}

fn random_process(rng: &mut Rng) -> ArrivalProcess {
    match rng.index(4) {
        0 => ArrivalProcess::Poisson { rate_per_min: 6.0 + rng.gen_f64() * 18.0 },
        1 => ArrivalProcess::Mmpp {
            on_rate_per_min: 20.0 + rng.gen_f64() * 30.0,
            off_rate_per_min: 1.0,
            mean_on_s: 30.0 + rng.gen_f64() * 40.0,
            mean_off_s: 30.0 + rng.gen_f64() * 60.0,
        },
        2 => ArrivalProcess::Diurnal {
            base_rate_per_min: 6.0 + rng.gen_f64() * 10.0,
            amplitude: rng.gen_f64(),
            period_s: 120.0 + rng.gen_f64() * 240.0,
        },
        _ => ArrivalProcess::ClosedLoop { users: 2 + rng.index(6) as u32, think_s: 15.0 },
    }
}

fn kind_of(i: usize) -> SchedKind {
    [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi][i % 3]
}

fn assert_accuracy_identities(m: &Metrics, ladder: &Ladder, ctx: &str) -> Result<(), String> {
    let met = m.lp_deadline_met();
    let per_rung: u64 = m.rung_completions.iter().sum();
    if per_rung != met {
        return Err(format!("{ctx}: Σ rung_completions {per_rung} != deadline-met {met}"));
    }
    let degraded: u64 = m.rung_completions[1..].iter().sum();
    if degraded != m.degraded_completions {
        return Err(format!(
            "{ctx}: degraded_completions {} != Σ rung_completions[1..] {degraded}",
            m.degraded_completions
        ));
    }
    if ladder.depth() == 1 && (m.degraded_completions > 0 || m.degraded_placements > 0) {
        return Err(format!("{ctx}: a one-rung ladder degraded"));
    }
    if met > 0 {
        let mean = m.accuracy_per_deadline_met();
        let max_acc = ladder.rungs.first().map(|r| r.accuracy).unwrap_or(1.0);
        let min_acc = ladder.rungs.last().map(|r| r.accuracy).unwrap_or(1.0);
        if !(min_acc - 1e-9..=max_acc + 1e-9).contains(&mean) {
            return Err(format!(
                "{ctx}: mean delivered accuracy {mean} outside rung bounds [{min_acc}, {max_acc}]"
            ));
        }
    }
    if m.offered_tasks
        != m.hp_generated + m.lp_generated + m.admission_dropped + m.offline_dropped
    {
        return Err(format!("{ctx}: offered-load identity broke through degradation"));
    }
    Ok(())
}

#[test]
fn accuracy_identities_hold_across_random_ladders_and_processes() {
    forall("accuracy identities (random ladder × process × scheduler)", 8, |rng| {
        let cfg = SystemConfig::default();
        let ladder = random_ladder(rng, &cfg);
        let process = random_process(rng);
        let kind = kind_of(rng.index(3));
        let seed = rng.next_u64();
        let catalog = Catalog::new(vec![TaskClass::low(
            "stage3",
            cfg.frame_period_s * (0.8 + rng.gen_f64() * 0.8),
            0.0,
            1.0,
            0.8,
        )
        .batch(1 + rng.index(2) as u32)
        .ladder(ladder.clone())]);
        let m = ScenarioBuilder::new()
            .scheduler(kind)
            .workload(Workload::generative(process, catalog))
            .minutes(5.0)
            .seed(seed)
            .build()
            .run();
        if m.gen_arrivals == 0 {
            return Err("plan fired no arrivals".to_string());
        }
        assert_accuracy_identities(&m, &ladder, &m.label)
    });
}

/// One frontier cell: the stage-3 family truncated to `depth` under
/// MMPP pressure at `rate` arrivals/min (ON state).
fn frontier_run(kind: SchedKind, depth: usize, rate: f64, seed: u64, minutes: f64) -> Metrics {
    let cfg = SystemConfig::default();
    ScenarioBuilder::new()
        .scheduler(kind)
        .workload(Workload::generative(frontier_arrivals(rate), frontier_catalog(&cfg, depth)))
        .minutes(minutes)
        .seed(seed)
        .named(format!("{}_d{depth}_s{seed}", kind.label()))
        .build()
        .run()
}

/// THE acceptance criterion: under MMPP overload, a 3-rung ladder shows
/// `deadline_met` strictly higher and mean delivered accuracy strictly
/// lower than its no-degradation twin — for every scheduler.
#[test]
fn overload_frontier_trades_accuracy_for_deadlines_strictly() {
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi] {
        let twin = frontier_run(kind, 1, 40.0, 2025, 12.0);
        let deep = frontier_run(kind, 3, 40.0, 2025, 12.0);
        assert!(
            twin.lp_deadline_met() > 0,
            "{}: the twin should complete some full-accuracy work in OFF windows",
            kind.label()
        );
        assert!(
            deep.degraded_completions > 0,
            "{}: overload must force degraded completions",
            kind.label()
        );
        assert!(
            deep.lp_deadline_met() > twin.lp_deadline_met(),
            "{}: degradation must strictly raise deadlines met ({} vs {})",
            kind.label(),
            deep.lp_deadline_met(),
            twin.lp_deadline_met()
        );
        assert!(
            deep.accuracy_per_deadline_met() < twin.accuracy_per_deadline_met() - 1e-6,
            "{}: degradation must strictly lower mean delivered accuracy ({:.4} vs {:.4})",
            kind.label(),
            deep.accuracy_per_deadline_met(),
            twin.accuracy_per_deadline_met()
        );
        // The twin runs the full model only: its mean is rung 0's
        // accuracy exactly (up to summation rounding).
        assert!((twin.accuracy_per_deadline_met() - 0.97).abs() < 1e-9, "{}", kind.label());
        // The trade is worth it in accuracy mass: the deep ladder
        // delivers at least as much total accuracy per offered task.
        assert!(
            deep.delivered_accuracy_rate() >= twin.delivered_accuracy_rate(),
            "{}: accuracy goodput should not fall ({:.4} vs {:.4})",
            kind.label(),
            deep.delivered_accuracy_rate(),
            twin.delivered_accuracy_rate()
        );
    }
}

/// Monotonicity: adding a lower rung never *systematically* reduces the
/// deadline-met count for the same seed. A strict per-seed guarantee is
/// not structural — the first degradation forks the whole trajectory
/// (placements shift, the schedulers' RNG streams advance differently,
/// jitter draws land on different tasks), so a deeper ladder can lose a
/// handful of completions to butterfly effects. What must hold is: per
/// seed, the deeper ladder is never more than noise below the shallower
/// one; and in aggregate over seeds the deeper ladder strictly wins
/// under pressure.
#[test]
fn adding_rungs_never_systematically_reduces_deadlines_met() {
    let tolerance = |shallow: u64| 2 + shallow / 20; // noise bound: 5 % + 2
    let mut total = [0u64; 3];
    for kind in [SchedKind::Wps, SchedKind::Ras] {
        for seed in [11u64, 12] {
            let met: Vec<u64> = (1..=3)
                .map(|depth| frontier_run(kind, depth, 30.0, seed, 8.0).lp_deadline_met())
                .collect();
            for (d, w) in met.windows(2).enumerate() {
                assert!(
                    w[1] + tolerance(w[0]) >= w[0],
                    "{} seed {seed}: depth {} met {} fell below depth {} met {} beyond noise",
                    kind.label(),
                    d + 2,
                    w[1],
                    d + 1,
                    w[0]
                );
            }
            for (i, &m) in met.iter().enumerate() {
                total[i] += m;
            }
        }
    }
    assert!(
        total[2] > total[0],
        "aggregate: the 3-rung ladder must strictly beat depth 1 under pressure ({total:?})"
    );
    assert!(
        total[1] >= total[0],
        "aggregate: the 2-rung ladder must not lose to depth 1 ({total:?})"
    );
}
