//! End-to-end simulator integration: whole experiment runs across
//! schedulers, loads, and mechanisms, checking the paper's qualitative
//! claims and cross-run identities.

use medge::config::SystemConfig;
use medge::experiments::{fig6_fig7, fig8_table2, frames_for_minutes, run_scenario, SchedKind};
use medge::metrics::Metrics;
use medge::workload::trace::TraceSpec;

fn run(kind: SchedKind, spec: TraceSpec, minutes: f64, seed: u64) -> Metrics {
    let cfg = SystemConfig { seed, ..Default::default() };
    let frames = frames_for_minutes(&cfg, minutes);
    run_scenario(&cfg, kind, spec, frames, "t")
}

#[test]
fn both_schedulers_complete_most_frames_under_light_load() {
    for kind in [SchedKind::Wps, SchedKind::Ras] {
        let m = run(kind, TraceSpec::Weighted(1), 20.0, 3);
        assert!(
            m.frame_completion_rate() > 0.7,
            "{kind:?} at W1: {:.2}",
            m.frame_completion_rate()
        );
    }
}

#[test]
fn completion_degrades_with_load() {
    for kind in [SchedKind::Wps, SchedKind::Ras] {
        let w1 = run(kind, TraceSpec::Weighted(1), 20.0, 5).frame_completion_rate();
        let w4 = run(kind, TraceSpec::Weighted(4), 20.0, 5).frame_completion_rate();
        assert!(w4 < w1, "{kind:?}: W4 ({w4:.2}) should be below W1 ({w1:.2})");
    }
}

#[test]
fn ras_scheduling_latency_is_far_below_wps_under_load() {
    let wps = run(SchedKind::Wps, TraceSpec::Weighted(4), 20.0, 7);
    let ras = run(SchedKind::Ras, TraceSpec::Weighted(4), 20.0, 7);
    // The paper's headline: the abstraction model trades accuracy for an
    // order-of-magnitude latency win.
    assert!(
        wps.lat_lp_alloc.mean_ms() > 10.0 * ras.lat_lp_alloc.mean_ms(),
        "WPS {:.2} ms vs RAS {:.2} ms",
        wps.lat_lp_alloc.mean_ms(),
        ras.lat_lp_alloc.mean_ms()
    );
    assert!(wps.lat_hp_preempt.mean_ms() > ras.lat_hp_preempt.mean_ms());
}

#[test]
fn wps_violates_more_deadlines_under_load() {
    let wps = run(SchedKind::Wps, TraceSpec::Weighted(4), 25.0, 9);
    let ras = run(SchedKind::Ras, TraceSpec::Weighted(4), 25.0, 9);
    assert!(
        wps.lp_violations > ras.lp_violations,
        "WPS viol {} vs RAS viol {}",
        wps.lp_violations,
        ras.lp_violations
    );
}

#[test]
fn ras_reallocates_under_every_load() {
    for n in 1..=4 {
        let m = run(SchedKind::Ras, TraceSpec::Weighted(n), 25.0, 11);
        assert!(
            m.lp_realloc_success > 0,
            "RAS W{n} should reallocate preempted tasks (attempts {})",
            m.lp_realloc_attempts
        );
    }
}

#[test]
fn frequent_bandwidth_probes_hurt_completion() {
    // Fig. 6/7: completion improves as the probe interval grows.
    let cfg = SystemConfig { seed: 13, ..Default::default() };
    let runs = fig6_fig7(&cfg, 20.0);
    let fastest = runs.first().unwrap(); // 1.5 s interval
    let slowest = runs.last().unwrap(); // 30 s interval
    assert!(fastest.bandwidth_updates > slowest.bandwidth_updates);
    assert!(
        slowest.frames_completed >= fastest.frames_completed,
        "30 s interval ({}) should beat 1.5 s ({})",
        slowest.frames_completed,
        fastest.frames_completed
    );
}

#[test]
fn congestion_reduces_completion_and_shifts_core_mix() {
    // Fig. 8 + Table II.
    let cfg = SystemConfig { seed: 17, ..Default::default() };
    let runs = fig8_table2(&cfg, 20.0);
    let quiet = &runs[0];
    let heavy = &runs[3];
    assert!(
        heavy.frames_completed < quiet.frames_completed,
        "75% duty ({}) should complete fewer frames than 0% ({})",
        heavy.frames_completed,
        quiet.frames_completed
    );
    // Core mix: four-core share grows under congestion.
    assert!(
        heavy.core_mix().1 >= quiet.core_mix().1,
        "four-core share should grow: quiet {:?} heavy {:?}",
        quiet.core_mix(),
        heavy.core_mix()
    );
}

#[test]
fn accounting_identities_hold_everywhere() {
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi] {
        for n in [1, 4] {
            let m = run(kind, TraceSpec::Weighted(n), 15.0, 23);
            assert_eq!(
                m.hp_generated,
                m.hp_allocated_no_preempt + m.hp_allocated_with_preempt + m.hp_rejected,
                "{kind:?} W{n}"
            );
            assert!(m.frames_completed <= m.frames_total);
            assert!(m.offloaded_completed <= m.offloaded_total);
            assert_eq!(
                m.two_core_allocs + m.four_core_allocs,
                m.lp_allocated_initial + m.lp_realloc_success,
                "{kind:?} W{n}: core mix"
            );
        }
    }
}

#[test]
fn runs_are_reproducible() {
    let a = run(SchedKind::Ras, TraceSpec::Weighted(3), 15.0, 31);
    let b = run(SchedKind::Ras, TraceSpec::Weighted(3), 15.0, 31);
    assert_eq!(format!("{a:?}"), format!("{b:?}"));
}

#[test]
fn multi_scheduler_tracks_the_better_of_both() {
    // Future-work ablation: the contextual multi-scheduler should not be
    // catastrophically worse than either pure scheduler at either extreme.
    let w1_multi = run(SchedKind::Multi, TraceSpec::Weighted(1), 20.0, 37).frame_completion_rate();
    let w1_best = run(SchedKind::Wps, TraceSpec::Weighted(1), 20.0, 37)
        .frame_completion_rate()
        .max(run(SchedKind::Ras, TraceSpec::Weighted(1), 20.0, 37).frame_completion_rate());
    assert!(w1_multi > w1_best - 0.15, "multi {w1_multi:.2} vs best {w1_best:.2}");
}
