//! Golden-trace regression suite: one fixed-seed scenario per scheduler
//! (WPS, RAS, MULTI) — with churn, heterogeneity, a mid-run congestion
//! regime, and a full fault plan (crash/recover, lossy link, probe loss)
//! so that every engine path PR 1 rewired and PR 2 added is locked down —
//! serialized through `report::json_rows` and compared **byte for byte**
//! against checked-in snapshots in `rust/tests/golden/`.
//!
//! A drifting snapshot means an intended semantic change or an accidental
//! one; either way it must be looked at. To regenerate after an intended
//! change:
//!
//! ```sh
//! UPDATE_GOLDEN=1 cargo test --test golden_trace
//! git diff rust/tests/golden/   # review, then commit
//! ```
//!
//! The actual rows are always written to `rust/target/golden_actual/`
//! (CI uploads that directory as an artifact when the suite fails, so
//! the diff is inspectable without re-running locally). A missing
//! snapshot bootstraps locally (written + loud warning — commit it to
//! arm the comparison) but FAILS under CI (`CI` env set): a fresh CI
//! checkout must never let the suite pass vacuously.

use std::path::PathBuf;

use medge::metrics::report;
use medge::scenario::{ScenarioBuilder, SchedKind};
use medge::workload::trace::TraceSpec;

/// The pinned scenario builder: fixed seed, every scenario feature
/// exercised. Changing ANY knob here invalidates the snapshots —
/// regenerate.
fn golden_builder(kind: SchedKind) -> ScenarioBuilder {
    ScenarioBuilder::new()
        .scheduler(kind)
        .trace(TraceSpec::Weighted(3))
        .frames(16)
        .seed(2024)
        .device_speed(1, 1.25)
        .leave_at(90.0, 2)
        .join_at(200.0, 2)
        .congestion_at(120.0, 36e6, 0.5)
        .crash_at(60.0, 3)
        .recover_at(150.0, 3)
        .loss_rate(0.05)
        .probe_loss(0.25)
        .named(format!("G_{}", kind.label()))
}

fn golden_scenario(kind: SchedKind) -> medge::metrics::Metrics {
    golden_builder(kind).build().run()
}

fn check(name: &str, kind: SchedKind) {
    let rows = report::json_rows(&[golden_scenario(kind)]);
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let golden = manifest.join("tests/golden").join(format!("{name}.json"));
    // Always drop the actual rows where CI can pick them up as a diff
    // artifact on failure.
    let actual_dir = manifest.join("target/golden_actual");
    std::fs::create_dir_all(&actual_dir).expect("create golden_actual dir");
    std::fs::write(actual_dir.join(format!("{name}.json")), &rows).expect("write actual rows");

    if std::env::var_os("UPDATE_GOLDEN").is_some() || !golden.exists() {
        // A missing snapshot must not silently pass forever on CI (every
        // checkout is fresh there — the byte-compare would never arm):
        // bootstrap locally, fail loudly under CI until the generated
        // files are committed.
        assert!(
            std::env::var_os("UPDATE_GOLDEN").is_some() || std::env::var_os("CI").is_none(),
            "golden snapshot {} is missing on CI: generate it locally \
             (UPDATE_GOLDEN=1 cargo test --test golden_trace) and commit rust/tests/golden/",
            golden.display()
        );
        std::fs::create_dir_all(golden.parent().unwrap()).expect("create golden dir");
        std::fs::write(&golden, &rows).expect("write golden snapshot");
        eprintln!(
            "golden_trace: wrote snapshot {} — review and commit it",
            golden.display()
        );
        return;
    }
    let expected = std::fs::read_to_string(&golden).expect("read golden snapshot");
    assert_eq!(
        expected, rows,
        "golden trace drifted for {name}: inspect rust/target/golden_actual/{name}.json \
         against rust/tests/golden/{name}.json; if the change is intended, regenerate with \
         `UPDATE_GOLDEN=1 cargo test --test golden_trace` and commit the diff"
    );
}

#[test]
fn golden_wps() {
    check("wps", SchedKind::Wps);
}

#[test]
fn golden_ras() {
    check("ras", SchedKind::Ras);
}

#[test]
fn golden_multi() {
    check("multi", SchedKind::Multi);
}

#[test]
fn golden_greedy() {
    check("greedy", SchedKind::Greedy);
}

/// The snapshot pipeline itself must be deterministic: serializing the
/// same scenario twice gives identical bytes (if this fails, no snapshot
/// can be trusted).
#[test]
fn golden_serialization_is_stable() {
    let a = report::json_rows(&[golden_scenario(SchedKind::Ras)]);
    let b = report::json_rows(&[golden_scenario(SchedKind::Ras)]);
    assert_eq!(a, b);
}

/// Degradation must be provably zero-cost when disabled: the golden
/// scenario with an explicit ONE-RUNG model-variant ladder (mirroring
/// the conveyor stage-3 class at accuracy 1.0) replays `json_rows`
/// **byte-identically** to the ladder-free run, for every scheduler —
/// through the full churn/fault/congestion path the snapshots pin. This
/// is also what keeps the checked-in goldens valid across the ladder
/// PR: the pre-ladder rows and the one-rung rows are the same bytes.
#[test]
fn one_rung_ladder_replays_golden_rows_byte_for_byte() {
    use medge::config::SystemConfig;
    use medge::workload::gen::{Ladder, ModelVariant};
    let cfg = SystemConfig::default();
    let one_rung = Ladder::single(ModelVariant::new(
        "stage3-full",
        1.0,
        cfg.image_bytes as f64 * 8.0 / 1e6,
        cfg.lp2_proc_s,
        cfg.lp4_proc_s,
    ));
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi] {
        let plain = report::json_rows(&[golden_scenario(kind)]);
        let laddered =
            report::json_rows(&[golden_builder(kind).lp_ladder(one_rung.clone()).build().run()]);
        assert_eq!(
            plain,
            laddered,
            "{}: a one-rung ladder must be byte-identical to no ladder",
            kind.label()
        );
    }
}

/// Energy accounting must be provably zero-cost when it measures
/// nothing: the golden scenario with the ZERO-WATT power model attached
/// replays `json_rows` **byte-identically** to the model-free run, for
/// every scheduler. The hooks fire at every state transition but draw
/// no RNG and integrate 0.0 everywhere, so both the simulation outcome
/// and the serialized energy fields (all zero) are the same bytes —
/// which is also what keeps the checked-in goldens valid across the
/// energy PR.
#[test]
fn zero_energy_model_replays_golden_rows_byte_for_byte() {
    use medge::energy::EnergyModel;
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi] {
        let plain = report::json_rows(&[golden_scenario(kind)]);
        let powered = report::json_rows(&[
            golden_builder(kind).energy(EnergyModel::zero()).build().run()
        ]);
        assert_eq!(
            plain,
            powered,
            "{}: the zero-watt power model must be byte-identical to no model",
            kind.label()
        );
    }
}

/// The robustness layer (PR 8) must be provably zero-cost when every
/// knob is off: the golden scenario with the failure detector, offload
/// timeout/retry, hedging, and bandwidth staleness all set to their
/// explicit OFF values (0 everywhere) replays `json_rows`
/// **byte-identically** to the untouched builder, for every scheduler —
/// through the full churn/fault/congestion path the snapshots pin. This
/// guards the off-values themselves: `detector(0, 0)` must construct a
/// disabled detector, not a hair-trigger one, and a zero timeout must
/// schedule nothing.
#[test]
fn zero_robustness_knobs_replay_golden_rows_byte_for_byte() {
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi] {
        let plain = report::json_rows(&[golden_scenario(kind)]);
        let knobbed = report::json_rows(&[golden_builder(kind)
            .detector(0, 0)
            .offload_timeout(0.0, 0)
            .hedge(0.0)
            .bw_stale_after(0)
            .build()
            .run()]);
        assert_eq!(
            plain,
            knobbed,
            "{}: explicit zero robustness knobs must be byte-identical to defaults",
            kind.label()
        );
    }
}

/// The observability layer (PR 9) must be provably zero-cost when off:
/// the golden scenario with the flight recorder and phase timers set to
/// their explicit OFF values (`record_trace(0)`, `timing(false)`)
/// replays `json_rows` **byte-identically** to the untouched builder,
/// for every scheduler — zero events, zero RNG draws, zeroed
/// `trace_events`/`phase_*_ns` fields. This is also what keeps the
/// checked-in goldens valid across the observability PR.
#[test]
fn zero_trace_knob_replays_golden_rows_byte_for_byte() {
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi] {
        let plain = report::json_rows(&[golden_scenario(kind)]);
        let knobbed = report::json_rows(&[golden_builder(kind)
            .record_trace(0)
            .timing(false)
            .build()
            .run()]);
        assert_eq!(
            plain,
            knobbed,
            "{}: explicit zero observability knobs must be byte-identical to defaults",
            kind.label()
        );
    }
}

/// The anytime layer (PR 10) must be provably zero-cost when off: the
/// golden scenario with the pressure controller set to its explicit OFF
/// values (`pressure(0.0, 0)`) replays `json_rows` **byte-identically**
/// to the untouched builder, for every scheduler — including the
/// energy- and greedy-policy ones the other zero-knob tests predate.
/// Without stage plans no boundary events exist and a zeroed survey
/// interval schedules nothing — zero events, zero RNG draws, zeroed
/// truncation/pressure fields. This is also what keeps the checked-in
/// goldens valid across the anytime PR.
#[test]
fn zero_anytime_knobs_replay_golden_rows_byte_for_byte() {
    for kind in [
        SchedKind::Wps,
        SchedKind::Ras,
        SchedKind::Multi,
        SchedKind::Energy,
        SchedKind::Greedy,
    ] {
        let plain = report::json_rows(&[golden_scenario(kind)]);
        let knobbed =
            report::json_rows(&[golden_builder(kind).pressure(0.0, 0).build().run()]);
        assert_eq!(
            plain,
            knobbed,
            "{}: explicit zero anytime knobs must be byte-identical to defaults",
            kind.label()
        );
    }
}

/// Determinism assertion for the fault path specifically: the golden
/// scenario crashes device 3 with work in flight, so every replay
/// exercises the crash orphan scan. That scan now iterates the medium's
/// id-sorted flow table (no sort — the engine debug-asserts the order),
/// and the replays must stay byte-identical for every scheduler.
#[test]
fn fault_paths_replay_identically() {
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi] {
        let a = report::json_rows(&[golden_scenario(kind)]);
        let b = report::json_rows(&[golden_scenario(kind)]);
        assert_eq!(a, b, "{}: faulted golden scenario drifted across replays", kind.label());
    }
}
