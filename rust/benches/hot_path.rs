//! Hot-path suite as a `cargo bench` target (`--bench hot_path`).
//! Installs the counting allocator so the steady-state `allocs/event`
//! gauge is measured; pass `quick` as an argument for the short CI
//! variant. `medge bench --json` runs the same suite and writes the
//! `BENCH_hotpath.json` trajectory file.

use medge::experiments::hotpath::{run_suite, SuiteOptions};
use medge::util::bench::CountingAlloc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn alloc_count() -> u64 {
    ALLOC.allocations()
}

fn main() {
    let quick = std::env::args().any(|a| a == "quick" || a == "--quick");
    println!("== hot_path bench suite (quick = {quick}) ==\n");
    let rows = run_suite(&SuiteOptions { quick, alloc_count: Some(alloc_count) });
    println!("\n{} rows; write the JSON trajectory with `medge bench --json`", rows.len());
}
