//! Ablation bench for the design choices DESIGN.md calls out:
//!
//! 1. Link discretisation geometry — base-bucket count vs exponential
//!    region (accuracy near `now` vs covered horizon).
//! 2. Controller op-cost sensitivity — where the accuracy-vs-performance
//!    crossover (Fig. 4) moves as the scheduler gets slower/faster.
//! 3. The future-work contextual multi-scheduler switch threshold.

use medge::config::SystemConfig;
use medge::experiments::{frames_for_minutes, run_scenario, SchedKind};
use medge::util::bench::bench_once;
use medge::workload::trace::TraceSpec;

fn main() {
    let minutes: f64 = std::env::var("MEDGE_BENCH_MINUTES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30.0);

    println!("== ablation 1: link geometry (RAS, weighted-4) ==");
    for (base, exp) in [(4usize, 13usize), (16, 11), (64, 9), (256, 5)] {
        let cfg = SystemConfig { base_buckets: base, exp_buckets: exp, ..Default::default() };
        let frames = frames_for_minutes(&cfg, minutes);
        let (m, _) = bench_once(&format!("base={base} exp={exp}"), || {
            run_scenario(&cfg, SchedKind::Ras, TraceSpec::Weighted(4), frames, "RAS")
        });
        println!(
            "    frames {:.1}%  lp_fail {}  offloaded {}/{}",
            m.frame_completion_rate() * 100.0,
            m.lp_alloc_failures,
            m.offloaded_completed,
            m.offloaded_total
        );
    }

    println!("\n== ablation 2: op-cost sensitivity (crossover position) ==");
    for op_cost in [50.0f64, 200.0, 800.0] {
        let cfg = SystemConfig { op_cost_us: op_cost, ..Default::default() };
        let frames = frames_for_minutes(&cfg, minutes);
        for n in [2u8, 3, 4] {
            let wps = run_scenario(&cfg, SchedKind::Wps, TraceSpec::Weighted(n), frames, "WPS");
            let ras = run_scenario(&cfg, SchedKind::Ras, TraceSpec::Weighted(n), frames, "RAS");
            println!(
                "op_cost {op_cost:>5} µs  W{n}: WPS {:.1}% vs RAS {:.1}%  ({})",
                wps.frame_completion_rate() * 100.0,
                ras.frame_completion_rate() * 100.0,
                if ras.frames_completed >= wps.frames_completed { "RAS" } else { "WPS" },
            );
        }
    }

    println!("\n== ablation 3: multi-scheduler switch threshold (weighted-3) ==");
    let frames = frames_for_minutes(&SystemConfig::default(), minutes);
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi] {
        let cfg = SystemConfig::default();
        let m = run_scenario(&cfg, kind, TraceSpec::Weighted(3), frames, kind.label());
        println!(
            "    {:<6} frames {:.1}%  lp_alloc {:.2} ms",
            kind.label(),
            m.frame_completion_rate() * 100.0,
            m.lat_lp_alloc.mean_ms()
        );
    }
}
