//! Regenerates Fig. 8 + Table II — network-traffic congestion tests:
//! duty-cycled background bursts at 0/25/50/75 % of the 30 s interval.

use medge::config::SystemConfig;
use medge::experiments::fig8_table2;
use medge::metrics::report;
use medge::util::bench::bench_once;

fn main() {
    let cfg = SystemConfig::default();
    let minutes: f64 = std::env::var("MEDGE_BENCH_MINUTES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30.0);
    let (runs, _) = bench_once(&format!("fig8+table2: 4 duty cycles × {minutes} min"), || {
        fig8_table2(&cfg, minutes)
    });
    print!("{}", report::fig8(&runs));
    print!("{}", report::table2(&runs));
    let q = &runs[0];
    let h = &runs[3];
    println!(
        "\nshape: frame drop 0% → 75%: {:.1}% (paper ~18%); four-core share {:.1}% → {:.1}% (paper 0% → 12.3%)",
        (q.frames_completed.saturating_sub(h.frames_completed)) as f64 / q.frames_completed.max(1) as f64 * 100.0,
        q.core_mix().1,
        h.core_mix().1
    );
}
