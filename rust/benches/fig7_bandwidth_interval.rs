//! Regenerates Fig. 7 — bandwidth-interval tests: task completion across
//! categories on a 30-min weighted-4 slice.

use medge::config::SystemConfig;
use medge::experiments::fig6_fig7;
use medge::metrics::report;
use medge::util::bench::bench_once;

fn main() {
    let cfg = SystemConfig::default();
    let minutes: f64 = std::env::var("MEDGE_BENCH_MINUTES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30.0);
    let (runs, _) = bench_once(&format!("fig7: 5 BIT scenarios × {minutes} min"), || {
        fig6_fig7(&cfg, minutes)
    });
    print!("{}", report::fig7(&runs));
    println!(
        "\nshape: frames 1.5 s → 30 s: {} → {} (paper: completion rises as probing slows)",
        runs.first().unwrap().frames_completed,
        runs.last().unwrap().frames_completed
    );
}
