//! Regenerates Fig. 5 — scheduling latency by initial allocation and
//! preemption/reallocation scenarios for both schedulers.

use medge::config::SystemConfig;
use medge::experiments::fig4_fig5;
use medge::metrics::report;
use medge::util::bench::bench_once;

fn main() {
    let cfg = SystemConfig::default();
    let minutes: f64 = std::env::var("MEDGE_BENCH_MINUTES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30.0);
    let (runs, _) = bench_once(&format!("fig5: 8 scenarios × {minutes} min"), || {
        fig4_fig5(&cfg, minutes)
    });
    print!("{}", report::fig5(&runs));
    let wps4 = runs.iter().find(|m| m.label == "WPS_4").unwrap();
    let ras4 = runs.iter().find(|m| m.label == "RAS_4").unwrap();
    println!(
        "\nshape: LP alloc W4 — WPS {:.1} ms vs RAS {:.2} ms ({:.0}× ; paper ~205 ms vs <6 ms)",
        wps4.lat_lp_alloc.mean_ms(),
        ras4.lat_lp_alloc.mean_ms(),
        wps4.lat_lp_alloc.mean_ms() / ras4.lat_lp_alloc.mean_ms().max(1e-9)
    );
    println!(
        "shape: preempt W4 — WPS {:.1} ms vs RAS {:.2} ms (paper ≥250 ms vs ≤100 ms)",
        wps4.lat_hp_preempt.mean_ms(),
        ras4.lat_hp_preempt.mean_ms()
    );
}
