//! Micro benchmarks of the paper's data structures — the raw wall-clock
//! counterpart of the ops-based latencies in Fig. 5. Demonstrates the
//! accuracy-vs-performance trade at the query level: RAS containment
//! (early-exit) vs WPS overlapping-range scan, as live-task count grows.

use std::time::Duration;

use medge::config::SystemConfig;
use medge::coordinator::netlink::{CommTask, DiscretisedLink};
use medge::coordinator::ras::DeviceAvailability;
use medge::coordinator::scheduler::WorkloadState;
use medge::coordinator::task::{Allocation, TaskConfig};
use medge::util::bench::bench;
use medge::util::Rng;

const SAMPLE: Duration = Duration::from_millis(300);

fn workload(n: usize, rng: &mut Rng) -> (WorkloadState, DeviceAvailability) {
    let cfg = SystemConfig::default();
    let mut state = WorkloadState::new(1);
    let mut avail = DeviceAvailability::new(&cfg, 0);
    for task in 0..n as u64 {
        let start = rng.gen_range(600_000_000);
        let end = start + 17_212_000;
        let a = Allocation {
            task,
            frame: task,
            device: 0,
            config: TaskConfig::LowTwoCore,
            cores: 2,
            start,
            end,
            deadline: end + 1_000_000,
            offloaded: false,
            comm: None,
        };
        state.insert(a);
        avail.write_all(start, end, 2);
    }
    (state, avail)
}

fn main() {
    println!("== micro_structures: query cost vs live-task count ==");
    let mut rng = Rng::seed_from_u64(42);
    for n in [8usize, 32, 128, 512] {
        let (state, avail) = workload(n, &mut rng);
        let mut qrng = Rng::seed_from_u64(7);
        bench(&format!("ras_containment_query/{n}_tasks"), SAMPLE, || {
            let t = qrng.gen_range(600_000_000);
            avail.query(TaskConfig::LowTwoCore, t, t + 17_212_000)
        });
        let mut qrng = Rng::seed_from_u64(7);
        bench(&format!("wps_overlap_scan/{n}_tasks"), SAMPLE, || {
            let t = qrng.gen_range(600_000_000);
            state.peak_usage(0, t, t + 17_212_000)
        });
    }

    println!("\n== discretised link ==");
    let link = DiscretisedLink::build(0, 30_000, 16, 11);
    let mut qrng = Rng::seed_from_u64(9);
    let horizon = link.horizon();
    bench("link_index_o1", SAMPLE, || {
        let t = qrng.gen_range(horizon);
        link.index(t)
    });
    let mut prng = Rng::seed_from_u64(11);
    bench("link_place_and_remove", SAMPLE, || {
        let mut l = link.clone();
        for task in 0..8u64 {
            let t = prng.gen_range(horizon / 2);
            let _ = l.place(t, horizon, CommTask { task, from: 0, to: 1, planned_start: t });
        }
        l.pending()
    });
    let mut full = link.clone();
    for task in 0..24u64 {
        let t = (task * 37_000) % (horizon / 2);
        let _ = full.place(t, horizon, CommTask { task, from: 0, to: 1, planned_start: t });
    }
    bench("link_rebuild_cascade_24_items", SAMPLE, || full.rebuild(100_000, 60_000));

    println!("\n== workload-state churn (position-indexed removal) ==");
    // Steady-state insert+remove at a fixed live-set size: the removal is
    // O(1) via the slot index (the seed layout paid an O(n) scan per
    // remove, which preemption/violation/churn hit once per live task).
    for n in [64usize, 512, 4096] {
        let cfg = SystemConfig::default();
        let mut w = WorkloadState::new(cfg.n_devices);
        let mk = |task: u64| Allocation {
            task,
            frame: task,
            device: (task % cfg.n_devices as u64) as usize,
            config: TaskConfig::LowTwoCore,
            cores: 2,
            start: (task % 97) * 500_000,
            end: (task % 97) * 500_000 + 17_212_000,
            deadline: (task % 97) * 500_000 + 18_860_000,
            offloaded: false,
            comm: None,
        };
        for t in 0..n as u64 {
            w.insert(mk(t));
        }
        let mut next = n as u64;
        bench(&format!("workload_state_insert_remove/{n}_live"), SAMPLE, || {
            let _ = w.remove(next - n as u64);
            w.insert(mk(next));
            next += 1;
            w.len()
        });
    }

    println!("\n== preemption reconstruction ==");
    let cfg = SystemConfig::default();
    for n in [4usize, 16, 64] {
        let (state, _) = workload(n, &mut rng);
        let allocs: Vec<Allocation> = state.allocations.values().cloned().collect();
        bench(&format!("ras_reconstruct/{n}_tasks"), SAMPLE, || {
            let mut d = DeviceAvailability::new(&cfg, 0);
            d.reconstruct(&cfg, 0, allocs.iter());
            d.window_count()
        });
    }
}
