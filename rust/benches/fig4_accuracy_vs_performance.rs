//! Regenerates Fig. 4 — "Task Completion across various categories":
//! WPS_N vs RAS_N over the weighted 1..4 loads. Also prints wall time per
//! scenario (the whole-run cost of each scheduler).

use medge::config::SystemConfig;
use medge::experiments::fig4_fig5;
use medge::metrics::report;
use medge::util::bench::bench_once;

fn main() {
    let cfg = SystemConfig::default();
    let minutes: f64 = std::env::var("MEDGE_BENCH_MINUTES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30.0);
    let (runs, _) = bench_once(&format!("fig4: 8 scenarios × {minutes} min"), || {
        fig4_fig5(&cfg, minutes)
    });
    print!("{}", report::fig4(&runs));
    // Shape checks the paper's narrative expects (soft-reported, not
    // asserted: this is a bench, not a test).
    let rate = |label: &str| {
        runs.iter()
            .find(|m| m.label == label)
            .map(|m| m.frame_completion_rate())
            .unwrap_or(0.0)
    };
    println!("\nshape: W1 WPS {:.3} vs RAS {:.3} (paper: WPS ahead)", rate("WPS_1"), rate("RAS_1"));
    println!("shape: W2 WPS {:.3} vs RAS {:.3} (paper: ~equal)", rate("WPS_2"), rate("RAS_2"));
    println!("shape: W3 WPS {:.3} vs RAS {:.3} (paper: RAS ahead)", rate("WPS_3"), rate("RAS_3"));
    println!("shape: W4 WPS {:.3} vs RAS {:.3} (paper: RAS ahead, gap grows)", rate("WPS_4"), rate("RAS_4"));
}
