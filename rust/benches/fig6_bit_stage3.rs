//! Regenerates Fig. 6 — low-priority (stage-3) completion by mechanism
//! across bandwidth-interval scenarios (BIT 1.5/5/10/20/30 s).

use medge::config::SystemConfig;
use medge::experiments::fig6_fig7;
use medge::metrics::report;
use medge::util::bench::bench_once;

fn main() {
    let cfg = SystemConfig::default();
    let minutes: f64 = std::env::var("MEDGE_BENCH_MINUTES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30.0);
    let (runs, _) = bench_once(&format!("fig6: 5 BIT scenarios × {minutes} min"), || {
        fig6_fig7(&cfg, minutes)
    });
    print!("{}", report::fig6(&runs));
    println!(
        "\nshape: LP completed 1.5 s → 30 s: {} → {} (paper: rises with interval)",
        runs.first().unwrap().lp_completed_total(),
        runs.last().unwrap().lp_completed_total()
    );
}
