//! Development diagnostic: RAS rejection-reason breakdown per load, plus
//! the churn stress (device 3 leaving and rejoining) the scenario API adds.
use medge::scenario::{ScenarioBuilder, SchedKind};
use medge::workload::trace::TraceSpec;

fn main() {
    for n in 1..=4 {
        let m = ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(n))
            .frames(95)
            .named(format!("RAS_{n}"))
            .build()
            .run();
        println!(
            "RAS_{n}: init={:<4} fail={:<4} realloc_ok={:<3}/{:<3} reasons[cfg,link,win,commit]={:?}",
            m.lp_allocated_initial, m.lp_alloc_failures, m.lp_realloc_success, m.lp_realloc_attempts, m.reject_reasons
        );
    }
    // Same load, but device 3 drops out for ~5 minutes mid-run.
    let m = ScenarioBuilder::new()
        .scheduler(SchedKind::Ras)
        .trace(TraceSpec::Weighted(3))
        .frames(95)
        .leave_at(400.0, 3)
        .join_at(700.0, 3)
        .named("RAS_3+churn")
        .build()
        .run();
    println!(
        "RAS_3+churn: evicted={} joins={} leaves={} init={} fail={} realloc_ok={}/{}",
        m.churn_evicted,
        m.churn_joins,
        m.churn_leaves,
        m.lp_allocated_initial,
        m.lp_alloc_failures,
        m.lp_realloc_success,
        m.lp_realloc_attempts
    );
}
