//! Development diagnostic: RAS rejection-reason breakdown per load.
use medge::config::SystemConfig;
use medge::experiments::{run_scenario, SchedKind};
use medge::workload::trace::TraceSpec;

fn main() {
    let cfg = SystemConfig::default();
    for n in 1..=4 {
        let m = run_scenario(&cfg, SchedKind::Ras, TraceSpec::Weighted(n), 95, &format!("RAS_{n}"));
        println!(
            "RAS_{n}: init={:<4} fail={:<4} realloc_ok={:<3}/{:<3} reasons[cfg,link,win,commit]={:?}",
            m.lp_allocated_initial, m.lp_alloc_failures, m.lp_realloc_success, m.lp_realloc_attempts, m.reject_reasons
        );
    }
}
