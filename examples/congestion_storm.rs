//! Walkthrough of the paper's network-traffic congestion experiment
//! (Section VI-C / Fig. 8): bursty background traffic at increasing duty
//! cycles degrades offloading, and the dynamic bandwidth mechanism
//! compensates by allocating more four-core (faster) configurations.
//!
//!     cargo run --release --example congestion_storm

use medge::config::SystemConfig;
use medge::experiments::fig8_table2;
use medge::metrics::report;

fn main() {
    let cfg = SystemConfig::default();
    let runs = fig8_table2(&cfg, 15.0);
    print!("{}", report::fig8(&runs));
    print!("{}", report::table2(&runs));

    let quiet = &runs[0];
    let heavy = &runs[3];
    let drop = (quiet.frames_completed as f64 - heavy.frames_completed as f64)
        / quiet.frames_completed.max(1) as f64
        * 100.0;
    println!("\nframe-completion drop 0% → 75% duty: {drop:.1}% (paper: ~18%)");
    println!(
        "bandwidth estimate after congestion: {:.1} Mb/s (true link: {:.1} Mb/s)",
        heavy.final_bandwidth_estimate_bps / 1e6,
        cfg.link_bps / 1e6
    );
}
