//! Walkthrough of the paper's network-traffic congestion experiment
//! (Section VI-C / Fig. 8): bursty background traffic at increasing duty
//! cycles degrades offloading, and the dynamic bandwidth mechanism
//! compensates by allocating more four-core (faster) configurations.
//! Also demonstrates the scenario API's *mid-run* regime change — a storm
//! that starts a third of the way through a quiet run, something the
//! paper's fixed figures cannot express.
//!
//!     cargo run --release --example congestion_storm

use medge::config::SystemConfig;
use medge::experiments::fig8_table2;
use medge::metrics::report;
use medge::scenario::{ScenarioBuilder, SchedKind};
use medge::workload::trace::TraceSpec;

fn main() {
    let cfg = SystemConfig::default();
    let runs = fig8_table2(&cfg, 15.0);
    print!("{}", report::fig8(&runs));
    print!("{}", report::table2(&runs));

    let quiet = &runs[0];
    let heavy = &runs[3];
    let drop = (quiet.frames_completed as f64 - heavy.frames_completed as f64)
        / quiet.frames_completed.max(1) as f64
        * 100.0;
    println!("\nframe-completion drop 0% → 75% duty: {drop:.1}% (paper: ~18%)");
    println!(
        "bandwidth estimate after congestion: {:.1} Mb/s (true link: {:.1} Mb/s)",
        heavy.final_bandwidth_estimate_bps / 1e6,
        cfg.link_bps / 1e6
    );

    // Beyond the paper: the storm arrives mid-run (minute 5 of 15) instead
    // of being on from the start. The estimator has settled on a quiet
    // link by then — watch it re-converge.
    let midrun = ScenarioBuilder::new()
        .scheduler(SchedKind::Ras)
        .trace(TraceSpec::Weighted(4))
        .minutes(15.0)
        .congestion_at(300.0, 36e6, 0.75)
        .named("storm@5min")
        .build()
        .run();
    println!(
        "\nmid-run storm (quiet first 5 min, 75% duty after): frames {}/{} ({:.1}%), est {:.1} Mb/s",
        midrun.frames_completed,
        midrun.frames_total,
        midrun.frame_completion_rate() * 100.0,
        midrun.final_bandwidth_estimate_bps / 1e6
    );
}
