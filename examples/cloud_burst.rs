//! Three-tier cookbook run: the cloud-burst and battery-drain story in
//! one sweep. An MMPP arrival storm swamps the 4-device edge fleet; each
//! scheduler runs an edge-only twin and a three-tier twin (cloud behind
//! a 20 Mb/s / 40 ms WAN), and the battery grid contrasts the
//! deadline-only schedulers with the energy-aware one on a tight
//! per-device joule budget. The energy table is the point: the cloud
//! twin buys strictly more deadlines under overload, and ENERGY buys
//! more deadlines per kilojoule when batteries are scarce.
//!
//! ```sh
//! cargo run --release --example cloud_burst
//! ```

use medge::config::SystemConfig;
use medge::energy::EnergyModel;
use medge::experiments;
use medge::metrics::report;
use medge::scenario::SchedKind;

fn main() {
    let cfg = SystemConfig { seed: 42, ..SystemConfig::default() };
    let kinds = [SchedKind::Wps, SchedKind::Ras, SchedKind::Energy];

    // Cloud burst: edge-only vs three-tier twins under MMPP overload.
    let burst = experiments::cloud_burst_grid(&cfg, &kinds, 12.0).run();
    print!("{}", report::energy(&burst));
    print!("{}", report::fig4(&burst));

    // Battery-constrained fleet: every device on a 1.5 kJ budget with
    // the Pi 2B power model; the comparison axis is deadlines per kJ.
    let battery =
        experiments::energy_battery_grid(&cfg, &kinds, 12.0, 1_500.0, &EnergyModel::pi2b())
            .run();
    print!("{}", report::energy(&battery));

    println!(
        "\nReading: every `_cloud` row beats its `_edge` twin on deadlines \
         met — the WAN tier is a spill valve, not a relocation. In the \
         battery grid, ENERGY's joule-scored placements and low-battery \
         steering stretch the same budget over more deadlines (met/kJ)."
    );
}
