//! Quickstart: compose the paper's testbed with the ScenarioBuilder, run
//! a few simulated minutes under both schedulers in parallel, and print
//! the headline metrics.
//!
//!     cargo run --release --example quickstart

use medge::config::SystemConfig;
use medge::scenario::{ScenarioBuilder, SchedKind, Sweep};
use medge::workload::trace::TraceSpec;

fn main() {
    let cfg = SystemConfig::default();
    println!(
        "network: {} devices × {} cores, {:.0} Mb/s link, frame period {:.2} s",
        cfg.n_devices,
        cfg.cores_per_device,
        cfg.link_bps / 1e6,
        cfg.frame_period_s
    );
    let mut sweep = Sweep::new();
    for kind in [SchedKind::Wps, SchedKind::Ras] {
        sweep = sweep.add(
            ScenarioBuilder::new()
                .scheduler(kind)
                .trace(TraceSpec::Weighted(3))
                .minutes(10.0)
                .named(kind.label())
                .build(),
        );
    }
    for m in sweep.run() {
        println!("\n[{}] 10 simulated minutes of weighted-3 load:", m.label);
        println!(
            "  frames {}/{} ({:.1}%)  lp completed {} (+{} reallocated)  violations {}",
            m.frames_completed,
            m.frames_total,
            m.frame_completion_rate() * 100.0,
            m.lp_completed_initial,
            m.lp_completed_realloc,
            m.lp_violations
        );
        println!(
            "  scheduling latency: hp {:.2} ms, lp {:.2} ms, preempt {:.2} ms",
            m.lat_hp_alloc.mean_ms(),
            m.lat_lp_alloc.mean_ms(),
            m.lat_hp_preempt.mean_ms()
        );
    }
    println!("\n(see `medge all` for every figure/table, `medge sweep` for custom grids)");
}
