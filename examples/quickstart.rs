//! Quickstart: simulate the paper's testbed for a few minutes under the
//! RAS scheduler and print the headline metrics.
//!
//!     cargo run --release --example quickstart

use medge::config::SystemConfig;
use medge::experiments::{frames_for_minutes, run_scenario, SchedKind};
use medge::workload::trace::TraceSpec;

fn main() {
    let cfg = SystemConfig::default();
    println!(
        "network: {} devices × {} cores, {:.0} Mb/s link, frame period {:.2} s",
        cfg.n_devices,
        cfg.cores_per_device,
        cfg.link_bps / 1e6,
        cfg.frame_period_s
    );
    let frames = frames_for_minutes(&cfg, 10.0);
    for kind in [SchedKind::Wps, SchedKind::Ras] {
        let m = run_scenario(&cfg, kind, TraceSpec::Weighted(3), frames, kind.label());
        println!(
            "\n[{}] 10 simulated minutes of weighted-3 load:",
            kind.label()
        );
        println!(
            "  frames {}/{} ({:.1}%)  lp completed {} (+{} reallocated)  violations {}",
            m.frames_completed,
            m.frames_total,
            m.frame_completion_rate() * 100.0,
            m.lp_completed_initial,
            m.lp_completed_realloc,
            m.lp_violations
        );
        println!(
            "  scheduling latency: hp {:.2} ms, lp {:.2} ms, preempt {:.2} ms",
            m.lat_hp_alloc.mean_ms(),
            m.lat_lp_alloc.mean_ms(),
            m.lat_hp_preempt.mean_ms()
        );
    }
    println!("\n(see `medge all` for every figure/table of the paper)");
}
