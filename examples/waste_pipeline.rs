//! End-to-end driver (DESIGN.md §End-to-end): the full three-layer stack
//! on a real workload.
//!
//! 1. Loads the AOT artifacts (`make artifacts`) — JAX/Pallas models
//!    lowered to HLO text — into the rust PJRT runtime.
//! 2. Replays a weighted-3 conveyor trace through the RAS scheduler on
//!    the simulated 4-device network.
//! 3. For every task the scheduler places, runs the *actual* DNN stage
//!    on the PJRT CPU client (detector+binary for high-priority work,
//!    the 4-class classifier for each low-priority task), batching
//!    per-frame requests exactly as the pipeline of Fig. 1 does.
//! 4. Reports scheduling metrics + real inference latency/throughput.
//!
//!     make artifacts && cargo run --release --example waste_pipeline

use std::time::Instant;

use medge::config::SystemConfig;
use medge::coordinator::scheduler::ras_sched::RasScheduler;
use medge::coordinator::scheduler::{task_refs, Outcome, SchedEvent, Scheduler};
use medge::coordinator::task::Task;
use medge::runtime::{default_artifacts_dir, image::synth_frame, InferenceEngine, Stage};
use medge::workload::trace::{Trace, TraceSpec};

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() as f64 * p) as usize).min(sorted.len() - 1)]
}

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    anyhow::ensure!(
        dir.join("detector.hlo.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let t0 = Instant::now();
    let engine = InferenceEngine::load(&dir)?;
    println!(
        "loaded 3 AOT stages on {} in {:.1} s",
        engine.platform(),
        t0.elapsed().as_secs_f64()
    );

    let cfg = SystemConfig::default();
    let trace = Trace::generate(TraceSpec::Weighted(3), cfg.n_devices, 24, cfg.seed);
    let mut sched = RasScheduler::new(&cfg, 0, cfg.link_bps);

    let mut id = 1u64;
    let mut hp_lat = Vec::new();
    let mut lp_lat = Vec::new();
    let mut inferences = 0u64;
    let mut frames_done = 0u64;
    let infer_t0 = Instant::now();

    for (row, entry) in trace.entries.iter().enumerate() {
        for (device, &load) in entry.loads.iter().enumerate() {
            if load < 0 {
                continue;
            }
            let now = (row * cfg.n_devices + device) as u64 * cfg.frame_period()
                / cfg.n_devices as u64;
            // --- high-priority stage: schedule, then really run the
            // detector + binary classifier on the frame.
            let frame_img = synth_frame(id, load > 0);
            let hp = Task::high(id, id, device, now, &cfg);
            id += 1;
            let _ = sched.on_event(now, SchedEvent::HighPriority { task: &hp });
            let t = Instant::now();
            let det = engine.infer(Stage::Detector, &frame_img)?;
            let _bin = engine.infer(Stage::Binary, &frame_img)?;
            hp_lat.push(t.elapsed().as_secs_f64() * 1e3);
            inferences += 2;
            let _ = det.argmax();

            // --- low-priority stage: batch of `load` classifier tasks.
            if load > 0 {
                let deadline = now + cfg.frame_period();
                let batch: Vec<Task> = (0..load as u64)
                    .map(|i| Task::low(id + i, hp.id, device, now, deadline, &cfg))
                    .collect();
                id += load as u64;
                let decision = sched.on_event(
                    now,
                    SchedEvent::LowPriorityBatch {
                        tasks: &task_refs(&batch),
                        realloc: false,
                        ladder: &[],
                    },
                );
                if let Outcome::LpAllocated { allocs } = decision.outcome {
                    for a in &allocs {
                        let img = synth_frame(a.task, true);
                        let t = Instant::now();
                        let logits = engine.infer(Stage::Classifier, &img)?;
                        lp_lat.push(t.elapsed().as_secs_f64() * 1e3);
                        inferences += 1;
                        assert!(logits.argmax() < 4);
                        sched.on_event(a.end, SchedEvent::Complete { task: a.task });
                    }
                    frames_done += 1;
                }
            } else {
                frames_done += 1;
            }
            sched.on_event(hp.created_at + cfg.hp_proc(), SchedEvent::Complete { task: hp.id });
        }
    }

    let wall = infer_t0.elapsed().as_secs_f64();
    hp_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lp_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!("\n=== waste_pipeline end-to-end report ===");
    println!("frames processed      : {frames_done}");
    println!("real inferences       : {inferences} in {wall:.1} s ({:.1} inf/s)", inferences as f64 / wall);
    println!(
        "detector+binary (ms)  : p50 {:.1}  p95 {:.1}",
        percentile(&hp_lat, 0.50),
        percentile(&hp_lat, 0.95)
    );
    println!(
        "classifier (ms)       : p50 {:.1}  p95 {:.1}",
        percentile(&lp_lat, 0.50),
        percentile(&lp_lat, 0.95)
    );
    println!("scheduler state live  : {}", sched.state().len());
    sched.check_invariants().map_err(|e| anyhow::anyhow!(e))?;
    println!("scheduler invariants  : OK");
    Ok(())
}
