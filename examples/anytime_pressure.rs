//! Anytime-truncation cookbook run: the staged stage-3 family (mandatory
//! backbone + optional refinement stages) under MMPP burst overload,
//! pressure controller off vs on, for all four LP policies including the
//! Fresa & Champati accuracy-maximizing GREEDY. The anytime table is the
//! point — the cut rows meet strictly more deadlines by shedding
//! refinement stages mid-flight, and accuracy goodput does not fall:
//! truncation spends tail accuracy the deadline would have wasted anyway.
//!
//! ```sh
//! cargo run --release --example anytime_pressure
//! ```

use medge::config::SystemConfig;
use medge::experiments::{anytime_catalog, frontier_arrivals, ANYTIME_BACKLOG, ANYTIME_CHECK_S};
use medge::metrics::report;
use medge::scenario::{ScenarioBuilder, SchedKind, Sweep};
use medge::workload::gen::Workload;

fn main() {
    let cfg = SystemConfig::default();
    let mut sweep = Sweep::new();
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi, SchedKind::Greedy] {
        for cut in [false, true] {
            let mut b = ScenarioBuilder::new()
                .config(cfg.clone())
                .scheduler(kind)
                // ON bursts at 40 arrivals/min (batch 2) — several times
                // what the full-depth model can serve inside the deadline.
                .workload(Workload::generative(
                    frontier_arrivals(40.0),
                    anytime_catalog(&cfg),
                ))
                .minutes(15.0)
                .seed(2025)
                .named(format!("{}_{}", kind.label(), if cut { "cut" } else { "full" }));
            if cut {
                b = b.pressure(ANYTIME_CHECK_S, ANYTIME_BACKLOG);
            }
            sweep = sweep.add(b.build());
        }
    }
    let runs = sweep.run();
    print!("{}", report::anytime(&runs));
    print!("{}", report::accuracy(&runs));
    for pair in runs.chunks(2) {
        let (full, cut) = (&pair[0], &pair[1]);
        println!(
            "{:<12} deadlines met {:>4} -> {:>4}  | truncated {:>4} ({} stages shed)  \
             | accuracy goodput {:.3} -> {:.3}",
            cut.label,
            full.lp_deadline_met(),
            cut.lp_deadline_met(),
            cut.truncated_completions,
            cut.stages_skipped,
            full.delivered_accuracy_rate(),
            cut.delivered_accuracy_rate(),
        );
    }
    println!(
        "\nReading: each '->' is the controller move — surveys cut live tasks \
         at the next stage boundary when the full depth would blow the \
         deadline (or the backlog escalates), so the mandatory backbone's \
         accuracy lands on time instead of a violation landing late."
    );
}
