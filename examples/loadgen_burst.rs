//! Bursty-overload cookbook run: a Markov-modulated on-off arrival storm
//! over the heterogeneous edge-serving catalog, with and without an
//! admission cap, contrasting all three schedulers. The percentile table
//! is the point — mean latency barely moves under burst, the p99 tail
//! explodes.
//!
//! ```sh
//! cargo run --release --example loadgen_burst
//! ```

use medge::config::SystemConfig;
use medge::metrics::report;
use medge::scenario::{ScenarioBuilder, SchedKind, Sweep};
use medge::workload::gen::{ArrivalProcess, Catalog, GenSpec, Workload};

fn main() {
    let cfg = SystemConfig::default();
    // ON bursts of ~45 s at 24 arrivals/min — several times the fleet's
    // stage-3 service capacity — separated by ~90 s of near-silence.
    let burst = ArrivalProcess::Mmpp {
        on_rate_per_min: 24.0,
        off_rate_per_min: 1.0,
        mean_on_s: 45.0,
        mean_off_s: 90.0,
    };
    let mut sweep = Sweep::new();
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi] {
        for (suffix, cap) in [("", 0usize), ("_cap", 24)] {
            sweep = sweep.add(
                ScenarioBuilder::new()
                    .config(cfg.clone())
                    .scheduler(kind)
                    .workload(Workload::Generative(GenSpec {
                        arrivals: burst.clone(),
                        catalog: Catalog::edge_serving(&cfg),
                        admission_cap: cap,
                    }))
                    .minutes(20.0)
                    .seed(42)
                    .named(format!("{}{}", kind.label(), suffix))
                    .build(),
            );
        }
    }
    let runs = sweep.run();
    print!("{}", report::loadgen(&runs));
    print!("{}", report::percentiles(&runs));
    println!(
        "\nReading: 'drops' trades rejected-at-the-door work for a bounded \
         p99 on what was admitted; the open rows queue everything and pay \
         for it in the tail."
    );
}
