//! Partition-vs-crash walkthrough (PR 8): the same weighted-4 workload
//! with a device *crashing* (work lost, oracle notice) versus the same
//! device *partitioned* (unreachable but alive: flows stall, results are
//! held until heal, nothing is force-lost) — first with the perfect
//! oracle only, then with the imperfect failure detector and the full
//! recovery policy (offload timeout + retry, hedged duplicates) armed.
//! Shows the partition builder API, the suspicion counters, and the
//! conservation identity closing in every regime.
//!
//!     cargo run --release --example partition_storm

use medge::metrics::report;
use medge::scenario::{ScenarioBuilder, SchedKind, Sweep};
use medge::workload::trace::TraceSpec;

fn main() {
    let base = || {
        ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(4))
            .minutes(15.0)
            .seed(42)
    };

    let mut sweep = Sweep::new();
    // 1. The ideal medium: no faults, no detector, the baseline row.
    sweep = sweep.add(base().named("clean").build());
    // 2. A crash: device 3 dies at minute 4 with work in flight and
    //    returns empty at minute 9. Its in-flight work is LOST.
    sweep = sweep.add(base().named("crash").crash_at(240.0, 3).recover_at(540.0, 3).build());
    // 3. The same window as a partition: device 3 is unreachable but
    //    alive. Transfers stall and resume from their captured progress
    //    at heal; results it finishes while cut off are delivered late
    //    (deadline permitting). Nothing is force-lost — the stall alone
    //    decides how many deadlines survive.
    sweep = sweep.add(base().named("partition").partition_at(240.0, 3).heal_at(540.0, 3).build());
    // 4. The partition again, but with imperfect detection and the
    //    recovery policy armed: the heartbeat detector suspects device 3
    //    after 2 missed probe rounds (schedulers place around the
    //    *belief*), stuck offloads time out and retry up to twice, and
    //    deadline-threatened placements race a hedged duplicate.
    sweep = sweep.add(
        base()
            .named("recovered")
            .partition_at(240.0, 3)
            .heal_at(540.0, 3)
            .probe_loss(0.15) // noise: seed-deterministic false suspicions
            .detector(2, 2)
            .offload_timeout(2.0, 2)
            .hedge(3.0)
            .bw_stale_after(3)
            .build(),
    );

    let runs = sweep.run();
    print!("{}", report::fig4(&runs));
    print!("{}", report::robustness(&runs));

    let (crash, part, rec) = (&runs[1], &runs[2], &runs[3]);
    println!(
        "\ncrash vs partition: crash lost {} tasks outright; the partition lost none by force \
         (stalled {} flows, held {} finished results for heal)",
        crash.crash_tasks_lost, part.partition_stalled_flows, part.partition_held_results,
    );
    println!(
        "detector: {} suspicions ({} false), mean detection lag {:.0} ms; \
         recovery: {} retries, {} hedges ({} won / {} wasted)",
        rec.devices_suspected,
        rec.false_suspicions,
        rec.lat_detection.mean_ms(),
        rec.retries,
        rec.hedges_launched,
        rec.hedges_won,
        rec.hedges_wasted,
    );
    // The ledger every regime must balance: offered == completed +
    // violated + lost (the chaos campaign hard-asserts this across
    // hundreds of randomized schedules — `medge chaos`).
    for m in &runs {
        assert_eq!(
            m.lp_generated,
            m.lp_completed_total() + m.lp_violations + m.lp_lost,
            "{}: conservation",
            m.label
        );
    }
    println!("conservation: offered == completed + violated + lost in all {} rows", runs.len());
}
