//! Accuracy-frontier cookbook run: the paper's stage-3 DNN with a
//! full/distilled/tiny model-variant ladder under MMPP burst overload,
//! ladder depth 1 (no degradation) vs 3, for all three schedulers. The
//! accuracy table is the point — the deep rows meet strictly more
//! deadlines at a strictly lower mean delivered accuracy, and RAS
//! (conservative windows) degrades earlier than WPS (exact state): the
//! title's accuracy-vs-performance trade-off, made literal.
//!
//! ```sh
//! cargo run --release --example accuracy_frontier
//! ```

use medge::config::SystemConfig;
use medge::experiments::{frontier_arrivals, frontier_catalog};
use medge::metrics::report;
use medge::scenario::{ScenarioBuilder, SchedKind, Sweep};
use medge::workload::gen::Workload;

fn main() {
    let cfg = SystemConfig::default();
    let mut sweep = Sweep::new();
    for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi] {
        for depth in [1usize, 3] {
            sweep = sweep.add(
                ScenarioBuilder::new()
                    .config(cfg.clone())
                    .scheduler(kind)
                    // ON bursts at 40 arrivals/min (batch 2) — several
                    // times what the full model can serve inside the
                    // 18.86 s deadline.
                    .workload(Workload::generative(
                        frontier_arrivals(40.0),
                        frontier_catalog(&cfg, depth),
                    ))
                    .minutes(15.0)
                    .seed(2025)
                    .named(format!("{}_d{}", kind.label(), depth))
                    .build(),
            );
        }
    }
    let runs = sweep.run();
    print!("{}", report::accuracy(&runs));
    print!("{}", report::loadgen(&runs));
    for pair in runs.chunks(2) {
        let (twin, deep) = (&pair[0], &pair[1]);
        println!(
            "{:<8} deadlines met {:>4} -> {:>4}  | mean accuracy {:.3} -> {:.3}  \
             | accuracy goodput {:.3} -> {:.3}",
            deep.label,
            twin.lp_deadline_met(),
            deep.lp_deadline_met(),
            twin.accuracy_per_deadline_met(),
            deep.accuracy_per_deadline_met(),
            twin.delivered_accuracy_rate(),
            deep.delivered_accuracy_rate(),
        );
    }
    println!(
        "\nReading: each '->' is the frontier move — degradation spends \
         per-inference accuracy to buy deadline compliance; the goodput \
         column shows the trade delivers more total accuracy mass, not less."
    );
}
