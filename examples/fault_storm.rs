//! Fault-injection walkthrough: the same weighted-4 workload on a clean
//! testbed, on a lossy link, and through a crash/recover storm — the
//! regimes the paper's shared-802.11n motivation describes but its fixed
//! figures cannot express. Shows the `FaultPlan` builder API, the crash
//! re-offer pipeline (lost → re-offered → placed → recovered-in-deadline)
//! and the fault counters in the report.
//!
//!     cargo run --release --example fault_storm

use medge::fault::FaultPlan;
use medge::metrics::report;
use medge::scenario::{ScenarioBuilder, SchedKind, Sweep};
use medge::workload::trace::TraceSpec;

fn main() {
    let base = || {
        ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(4))
            .minutes(15.0)
            .seed(42)
    };

    let mut sweep = Sweep::new();
    // 1. The paper's ideal medium.
    sweep = sweep.add(base().named("clean").build());
    // 2. A lossy link: 10% of packets are lost and retransmitted, a
    //    quarter of probe pings never return (rounds shrink or vanish).
    sweep = sweep.add(base().named("lossy").loss_rate(0.10).probe_loss(0.25).build());
    // 3. A crash storm: device 3 dies at minute 4 with work in flight
    //    and returns empty at minute 9; everything it was running is
    //    lost, surviving guests are re-offered to the scheduler.
    sweep = sweep.add(base().named("crash").crash_at(240.0, 3).recover_at(540.0, 3).build());
    // 4. All of it at once, plus a random background fault process
    //    (MTBF 6 min, MTTR 1 min) — attached as a composed FaultPlan.
    let storm = FaultPlan::new()
        .loss_rate(0.10)
        .probe_loss(0.25)
        .crash_at(240.0, 3)
        .recover_at(540.0, 3)
        .random_faults(360.0, 60.0);
    sweep = sweep.add(base().named("storm").faults(storm).build());

    let runs = sweep.run();
    print!("{}", report::fig4(&runs));
    print!("{}", report::faults(&runs));

    let clean = &runs[0];
    let storm = &runs[3];
    println!(
        "\nframe completion: clean {:.1}% -> storm {:.1}%  (crashes: {}, tasks lost: {}, \
         re-offered: {}, recovered in deadline: {})",
        clean.frame_completion_rate() * 100.0,
        storm.frame_completion_rate() * 100.0,
        storm.device_crashes,
        storm.crash_tasks_lost,
        storm.crash_tasks_reoffered,
        storm.crash_recovered_in_deadline,
    );
    println!(
        "lossy link: {:.1} Mbit retransmitted, {} probe pings lost, {} whole rounds lost",
        runs[1].retransmitted_mbits, runs[1].probe_pings_lost, runs[1].probe_rounds_lost,
    );
}
