//! Walkthrough of the bandwidth-interval trade-off (Section VI-B /
//! Figs. 6–7): probing too often congests the link and stalls the
//! controller on link-rebuilds; probing too rarely leaves the estimate
//! stale. The paper sweeps {1.5, 5, 10, 20, 30} s.
//!
//!     cargo run --release --example bandwidth_tuning

use medge::config::SystemConfig;
use medge::experiments::fig6_fig7;
use medge::metrics::report;

fn main() {
    let cfg = SystemConfig::default();
    let runs = fig6_fig7(&cfg, 15.0);
    print!("{}", report::fig6(&runs));
    print!("{}", report::fig7(&runs));
    println!("\ninterval  updates  rebuild_ops  frames");
    for m in &runs {
        println!(
            "{:<9} {:<8} {:<12} {}",
            m.label, m.bandwidth_updates, m.link_rebuild_ops, m.frames_completed
        );
    }
    println!("\n(the paper's finding: completion rises as the interval grows to 30 s)");
}
