"""Layer-1 correctness gate: the Pallas matmul kernel vs the pure-jnp
oracle, across shapes, dtypes, epilogues — including a hypothesis sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import pallas_matmul, BM, BN, BK
from compile.kernels.ref import ref_matmul


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


class TestMatmulBasics:
    def test_identity(self):
        x = jnp.eye(8, dtype=jnp.float32)
        y = _rand(0, (8, 8), jnp.float32)
        np.testing.assert_allclose(pallas_matmul(x, y), y, rtol=1e-6)

    def test_matches_ref_square(self):
        x = _rand(1, (32, 32), jnp.float32)
        y = _rand(2, (32, 32), jnp.float32)
        np.testing.assert_allclose(pallas_matmul(x, y), ref_matmul(x, y), rtol=1e-5, atol=1e-5)

    def test_non_multiple_shapes_are_padded(self):
        # Shapes that don't divide the block sizes exercise the pad/slice path.
        x = _rand(3, (37, 23), jnp.float32)
        y = _rand(4, (23, 11), jnp.float32)
        np.testing.assert_allclose(pallas_matmul(x, y), ref_matmul(x, y), rtol=1e-5, atol=1e-5)

    def test_larger_than_one_block(self):
        x = _rand(5, (BM + 32, BK * 2 + 8), jnp.float32)
        y = _rand(6, (BK * 2 + 8, BN + 16), jnp.float32)
        np.testing.assert_allclose(pallas_matmul(x, y), ref_matmul(x, y), rtol=1e-4, atol=1e-4)

    def test_vector_like(self):
        x = _rand(7, (1, 64), jnp.float32)
        y = _rand(8, (64, 1), jnp.float32)
        np.testing.assert_allclose(pallas_matmul(x, y), ref_matmul(x, y), rtol=1e-5, atol=1e-5)


class TestEpilogues:
    def test_bias(self):
        x = _rand(9, (16, 24), jnp.float32)
        y = _rand(10, (24, 8), jnp.float32)
        b = _rand(11, (8,), jnp.float32)
        np.testing.assert_allclose(
            pallas_matmul(x, y, bias=b), ref_matmul(x, y, bias=b), rtol=1e-5, atol=1e-5
        )

    @pytest.mark.parametrize("act", ["relu", "leaky_relu"])
    def test_activations(self, act):
        x = _rand(12, (16, 16), jnp.float32)
        y = _rand(13, (16, 16), jnp.float32)
        b = _rand(14, (16,), jnp.float32)
        np.testing.assert_allclose(
            pallas_matmul(x, y, bias=b, activation=act),
            ref_matmul(x, y, bias=b, activation=act),
            rtol=1e-5,
            atol=1e-5,
        )

    def test_leaky_slope_is_respected(self):
        x = -jnp.ones((8, 8), jnp.float32)
        y = jnp.eye(8, dtype=jnp.float32)
        out = pallas_matmul(x, y, activation="leaky_relu", leaky_slope=0.25)
        np.testing.assert_allclose(out, -0.25 * jnp.ones((8, 8)), rtol=1e-6)

    def test_no_activation_passes_negatives(self):
        x = -jnp.ones((4, 4), jnp.float32)
        y = jnp.eye(4, dtype=jnp.float32)
        np.testing.assert_allclose(pallas_matmul(x, y), x, rtol=1e-6)


class TestDtypes:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_support(self, dtype):
        x = _rand(15, (16, 32), dtype)
        y = _rand(16, (32, 8), dtype)
        got = pallas_matmul(x, y)
        want = ref_matmul(x, y)
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
        )

    def test_mixed_dtypes_promote(self):
        x = _rand(17, (8, 8), jnp.bfloat16)
        y = _rand(18, (8, 8), jnp.float32)
        assert pallas_matmul(x, y).dtype == jnp.float32


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 2 * BM + 3),
    k=st.integers(1, 2 * BK + 3),
    n=st.integers(1, BN + 5),
    seed=st.integers(0, 2**31 - 1),
    act=st.sampled_from([None, "relu", "leaky_relu"]),
    with_bias=st.booleans(),
)
def test_hypothesis_shape_sweep(m, k, n, seed, act, with_bias):
    """The kernel agrees with the oracle on arbitrary shapes/epilogues."""
    kx, ky, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    y = jax.random.normal(ky, (k, n), jnp.float32)
    b = jax.random.normal(kb, (n,), jnp.float32) if with_bias else None
    got = pallas_matmul(x, y, bias=b, activation=act)
    want = ref_matmul(x, y, bias=b, activation=act)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
