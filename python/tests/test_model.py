"""Layer-2 checks: conv-as-pallas-matmul vs the lax oracle, stage model
shapes, determinism, and pipeline semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ref_conv2d
from compile.model import (
    IMAGE_SIDE,
    N_RECYCLABLE_CLASSES,
    conv2d,
    forward,
    global_avg_pool,
    make_params,
)


class TestConv2d:
    def test_matches_lax_conv(self):
        key = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        x = jax.random.normal(k1, (1, 16, 16, 3), jnp.float32)
        w = jax.random.normal(k2, (3, 3, 3, 8), jnp.float32) * 0.2
        b = jax.random.normal(k3, (8,), jnp.float32) * 0.1
        got = conv2d(x, w, b, stride=2, activation="leaky_relu")
        want = ref_conv2d(x, w, b, stride=2, activation="leaky_relu")
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        side=st.sampled_from([8, 12, 16]),
        cin=st.integers(1, 4),
        cout=st.sampled_from([4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_conv_sweep(self, side, cin, cout, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(k1, (1, side, side, cin), jnp.float32)
        w = jax.random.normal(k2, (3, 3, cin, cout), jnp.float32) * 0.2
        b = jax.random.normal(k3, (cout,), jnp.float32) * 0.1
        got = conv2d(x, w, b)
        want = ref_conv2d(x, w, b)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_stride_halves_resolution(self):
        x = jnp.zeros((1, 16, 16, 3), jnp.float32)
        w = jnp.zeros((3, 3, 3, 4), jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        assert conv2d(x, w, b, stride=2).shape == (1, 8, 8, 4)


class TestStages:
    @pytest.mark.parametrize(
        "stage,n_out", [("detector", 2), ("binary", 2), ("classifier", N_RECYCLABLE_CLASSES)]
    )
    def test_output_shapes(self, stage, n_out):
        x = jnp.zeros((1, IMAGE_SIDE, IMAGE_SIDE, 3), jnp.float32)
        assert forward(stage, x).shape == (1, n_out)

    def test_deterministic_weights(self):
        a = make_params("classifier")
        b = make_params("classifier")
        for (wa, ba), (wb, bb) in zip(a["convs"], b["convs"]):
            np.testing.assert_array_equal(wa, wb)
            np.testing.assert_array_equal(ba, bb)

    def test_stages_have_distinct_weights(self):
        det = make_params("detector")
        bin_ = make_params("binary")
        assert det["convs"][0][0].shape != bin_["convs"][0][0].shape or not np.allclose(
            det["convs"][0][0], bin_["convs"][0][0]
        )

    def test_forward_varies_with_input(self):
        x0 = jnp.zeros((1, IMAGE_SIDE, IMAGE_SIDE, 3), jnp.float32)
        x1 = jnp.ones((1, IMAGE_SIDE, IMAGE_SIDE, 3), jnp.float32)
        assert not np.allclose(forward("classifier", x0), forward("classifier", x1))

    def test_gap_reduces_spatial(self):
        x = jnp.ones((2, 4, 4, 8), jnp.float32)
        assert global_avg_pool(x).shape == (2, 8)
