"""AOT gate: every stage lowers to non-trivial, ENTRY-bearing HLO text
(the exact format the rust runtime parses), and the Pallas kernel lowers
to plain HLO ops (no Mosaic custom-calls that the CPU client can't run)."""

import pytest

from compile.aot import STAGES, lower_stage


@pytest.fixture(scope="module")
def lowered():
    return {s: lower_stage(s) for s in STAGES}


def test_all_stages_lower(lowered):
    for stage in STAGES:
        assert len(lowered[stage]) > 10_000, f"{stage} HLO suspiciously small"


def test_hlo_has_entry(lowered):
    for stage, text in lowered.items():
        assert "ENTRY" in text, f"{stage} missing ENTRY computation"
        assert "f32[1,64,64,3]" in text, f"{stage} missing input parameter"


def test_no_mosaic_custom_calls(lowered):
    # interpret=True keeps the kernel executable on the CPU PJRT client.
    for stage, text in lowered.items():
        assert "tpu_custom_call" not in text, f"{stage} contains a Mosaic custom-call"


def test_outputs_are_tuples(lowered):
    # return_tuple=True: the rust side unwraps with to_tuple1().
    for stage, text in lowered.items():
        assert "(f32[1," in text.split("ENTRY")[1], f"{stage} entry should return a tuple"


def test_stage_list_matches_rust_runtime():
    assert STAGES == ("detector", "binary", "classifier")
