"""Layer 1: Pallas tiled matmul kernel — the compute hot-spot of every
pipeline stage (convolutions run as im2col + matmul; dense heads call it
directly).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's DNNs run
on Raspberry Pi CPUs via TFLite, so there is no GPU kernel to port.
We express the hot loop the TPU way regardless: the matmul is tiled over
an (M/bm, N/bn, K/bk) grid with VMEM-sized blocks shaped for the MXU
systolic array, accumulating partial products across the K dimension and
fusing the bias + leaky-ReLU epilogue into the final K step (one HBM
round-trip per output tile). `interpret=True` everywhere: the CPU PJRT
client cannot execute Mosaic custom-calls, and correctness is what the
build-time pytest checks; TPU perf is estimated statically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes: 128×128 output tiles match the MXU; 32-wide K slabs keep
# x/y blocks + accumulator well under VMEM (~(128·32 + 32·128 + 128·128)·4 B
# ≈ 98 kB of a ~16 MB VMEM).
BM = 128
BN = 128
BK = 32


def _matmul_kernel(x_ref, y_ref, b_ref, o_ref, *, n_k: int, slope: float, fuse_bias: bool):
    """One (bm, bn) output tile; grid axis 2 walks the K slabs."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype)

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = o_ref[...]
        if fuse_bias:
            acc = acc + b_ref[...]
        if slope >= 0.0:
            # leaky ReLU (slope=0 → plain ReLU); slope<0 disables.
            acc = jnp.where(acc > 0, acc, acc * slope)
        o_ref[...] = acc


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("activation",))
def _identity(x, activation=None):  # pragma: no cover - trivial
    return x


def pallas_matmul(
    x: jax.Array,
    y: jax.Array,
    bias: jax.Array | None = None,
    activation: str | None = None,
    leaky_slope: float = 0.1,
) -> jax.Array:
    """`activation(x @ y + bias)` as a tiled Pallas kernel.

    x: (M, K), y: (K, N), bias: (N,) or None.
    activation: None | "relu" | "leaky_relu".
    Inputs are zero-padded up to block multiples and the result sliced
    back, so arbitrary shapes are accepted.
    """
    assert x.ndim == 2 and y.ndim == 2, (x.shape, y.shape)
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    out_dtype = jnp.promote_types(x.dtype, y.dtype)

    bm = min(BM, _ceil_to(m, 8))
    bn = min(BN, _ceil_to(n, 8))
    bk = min(BK, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))).astype(out_dtype)
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n))).astype(out_dtype)
    if bias is None:
        bp = jnp.zeros((1, np_), out_dtype)
        fuse_bias = False
    else:
        assert bias.shape == (n,), bias.shape
        bp = jnp.pad(bias, (0, np_ - n)).astype(out_dtype)[None, :]
        fuse_bias = True

    slope = {None: -1.0, "relu": 0.0, "leaky_relu": leaky_slope}[activation]
    n_k = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k, slope=slope, fuse_bias=fuse_bias),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, yp, bp)
    return out[:m, :n]
