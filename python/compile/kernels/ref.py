"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth
used by the build-time pytest gate (and hypothesis sweeps)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_matmul(x, y, bias=None, activation=None, leaky_slope=0.1):
    """activation(x @ y + bias) in plain jnp."""
    out = jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))
    if bias is not None:
        out = out + bias
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "leaky_relu":
        out = jnp.where(out > 0, out, out * leaky_slope)
    elif activation is not None:
        raise ValueError(f"unknown activation {activation}")
    return out


def ref_conv2d(x, w, b, stride=2, activation="leaky_relu", leaky_slope=0.1):
    """NHWC conv + bias + activation via lax (oracle for the im2col path).

    x: (N, H, W, Cin); w: (kh, kw, Cin, Cout); b: (Cout,).
    'SAME' padding, square stride.
    """
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = out + b
    if activation == "leaky_relu":
        out = jnp.where(out > 0, out, out * leaky_slope)
    elif activation == "relu":
        out = jnp.maximum(out, 0.0)
    return out
