"""Layer 2: the three-stage waste-classification pipeline (paper Fig. 1)
as JAX models whose convolution/dense hot loops run through the Layer-1
Pallas matmul kernel (convs are lowered to im2col + tiled matmul).

Stage 1 — object detector: is waste present in the frame?
Stage 2 — binary classifier: recyclable vs non-recyclable.
Stage 3 — high-complexity classifier: four recyclable classes
          (YoloV2-flavoured: strided convs + leaky ReLU).

Weights are deterministic (fixed PRNG key per stage) and baked into the
AOT artifact as constants, so the rust runtime loads a self-contained
HLO module per stage.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels.matmul import pallas_matmul

IMAGE_SIDE = 64
N_RECYCLABLE_CLASSES = 4


def _im2col(x, kh, kw, stride):
    """NHWC → (N·H'·W', kh·kw·Cin) patch matrix ('SAME' padding)."""
    n, h, w, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # patches: (N, H', W', Cin·kh·kw) with channel-major patch layout.
    ho, wo = patches.shape[1], patches.shape[2]
    return patches.reshape(n * ho * wo, c * kh * kw), (n, ho, wo)


def conv2d(x, w, b, stride=2, activation="leaky_relu"):
    """Conv as im2col + the Pallas tiled matmul (bias+activation fused).

    w: (kh, kw, Cin, Cout) — reordered to match the patch layout
    (Cin-major) produced by conv_general_dilated_patches.
    """
    kh, kw, cin, cout = w.shape
    cols, (n, ho, wo) = _im2col(x, kh, kw, stride)
    w2 = jnp.transpose(w, (2, 0, 1, 3)).reshape(kh * kw * cin, cout)
    out = pallas_matmul(cols, w2, bias=b, activation=activation)
    return out.reshape(n, ho, wo, cout)


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def dense(x, w, b, activation=None):
    return pallas_matmul(x, w, bias=b, activation=activation)


def _init(key, shape, scale=None):
    fan_in = 1
    for d in shape[:-1]:
        fan_in *= d
    scale = scale or (2.0 / fan_in) ** 0.5
    return jax.random.normal(key, shape, jnp.float32) * scale


def _conv_stack(params, x):
    for w, b in params["convs"]:
        x = conv2d(x, w, b, stride=2, activation="leaky_relu")
    x = global_avg_pool(x)
    return dense(x, *params["head"])


def make_params(stage: str):
    """Deterministic weights per stage (fixed key ⇒ reproducible HLO)."""
    specs = {
        # (conv channel progression, classes)
        "detector": ([8, 16], 2),
        "binary": ([16, 16], 2),
        "classifier": ([16, 32, 64], N_RECYCLABLE_CLASSES),
    }
    chans, n_cls = specs[stage]
    key = jax.random.PRNGKey(sum(ord(c) for c in stage))
    convs = []
    cin = 3
    for cout in chans:
        key, k1 = jax.random.split(key)
        convs.append((_init(k1, (3, 3, cin, cout)), jnp.zeros((cout,), jnp.float32)))
        cin = cout
    key, k2 = jax.random.split(key)
    head = (_init(k2, (cin, n_cls)), jnp.zeros((n_cls,), jnp.float32))
    return {"convs": convs, "head": head}


@functools.partial(jax.jit, static_argnames=("stage",))
def forward(stage: str, x):
    """Run one pipeline stage on (1, 64, 64, 3) f32 frames → logits."""
    params = make_params(stage)
    return _conv_stack(params, x)


def stage_fn(stage: str):
    """A closed-over single-input function suitable for AOT lowering."""
    def fn(x):
        return (forward(stage, x),)

    return fn
