"""AOT-lower every pipeline stage to HLO text for the rust runtime.

HLO *text*, not `.serialize()`: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (what the published
`xla` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md and DESIGN.md.

Usage: python -m compile.aot --outdir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import IMAGE_SIDE, stage_fn

STAGES = ("detector", "binary", "classifier")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stage(stage: str) -> str:
    spec = jax.ShapeDtypeStruct((1, IMAGE_SIDE, IMAGE_SIDE, 3), jnp.float32)
    lowered = jax.jit(stage_fn(stage)).lower(spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--stages", nargs="*", default=list(STAGES))
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    for stage in args.stages:
        text = lower_stage(stage)
        path = os.path.join(args.outdir, f"{stage}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {stage}: {len(text)} chars -> {path}")


if __name__ == "__main__":
    main()
